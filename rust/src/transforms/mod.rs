//! The forelem transformations (paper §4–5) as legality-checked
//! transitions on `ChainState`. After each application the canonical IR
//! is reconstructable with `forelem::build::program`.
//!
//! | function | paper |
//! |---|---|
//! | `orthogonalize` (incl. encapsulation) | §4.1 |
//! | `localize` (loop collapse of token+data reservoirs) | §5.1, §2.3.1 |
//! | `hisr` (horizontal iteration-space reduction) | §4.3.1 |
//! | `materialize` (loop-dependent/-independent) | §4.2 |
//! | `split` (structure/tuple splitting) | §4.3.2 |
//! | `nstar_materialize` (padded/exact) | §4.3.3 |
//! | `nstar_sort` | §4.3.4 |
//! | `interchange` (post-materialization) | §5.2 |
//! | `dim_reduce` | §4.3.5 |
//! | `block` (tile / fill-cutoff) | §5.3, §6.2.3 |

use crate::baselines::Kernel;
use crate::forelem::ir::{Blocking, ChainState, NStarMat, Orth};

#[derive(Debug, PartialEq, Eq)]
pub enum TransformError {
    Illegal(&'static str),
}

impl std::fmt::Display for TransformError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransformError::Illegal(msg) => write!(f, "illegal transformation: {msg}"),
        }
    }
}

impl std::error::Error for TransformError {}

type R = Result<(), TransformError>;

fn illegal(msg: &'static str) -> R {
    Err(TransformError::Illegal(msg))
}

/// §4.1 — impose grouping on one or more tuple fields. Includes the
/// encapsulation of the introduced field-value loop(s) into ℕ ranges
/// (always legal for row/col indices, which are naturals).
pub fn orthogonalize(s: &mut ChainState, orth: Orth) -> R {
    if s.materialized.is_some() {
        return illegal("orthogonalization must precede materialization");
    }
    if s.orth != Orth::None {
        return illegal("already orthogonalized");
    }
    if orth == Orth::None {
        return illegal("orthogonalize requires a target field");
    }
    if orth == Orth::Diag && s.kernel == Kernel::Trsv {
        // forward substitution cannot be reordered by diagonals of A
        return illegal("diagonal orthogonalization breaks TrSv dependences");
    }
    s.orth = orth;
    s.history.push(match orth {
        Orth::Row => "orthogonalize(row)",
        Orth::Col => "orthogonalize(col)",
        Orth::RowCol => "orthogonalize(row,col)",
        Orth::Diag => "orthogonalize(col-row)",
        Orth::None => unreachable!(),
    });
    Ok(())
}

/// §5.1 — loop collapse of the token reservoir with its data reservoir:
/// `⟨row,col⟩` tokens and `A(t)` values become localized `⟨row,col,val⟩`
/// tuples. In this pipeline materialization performs the localization
/// implicitly; the explicit step exists so derivation listings can show
/// it (and is required before `hisr` can drop the data indirection).
pub fn localize(s: &mut ChainState) -> R {
    if s.materialized.is_some() {
        return illegal("already materialized (localization implied)");
    }
    if s.history.contains(&"localize") {
        return illegal("already localized");
    }
    s.history.push("localize");
    Ok(())
}

/// §4.3.1 — drop tuple fields the loop body does not use. For a
/// row-orthogonalized SpMV the `row` field becomes an induction variable
/// and is *not stored* (this is why CSR stores no row indices).
pub fn hisr(s: &mut ChainState) -> R {
    if s.hisr {
        return illegal("already reduced");
    }
    if s.orth == Orth::None {
        return illegal("no redundant field without orthogonalization");
    }
    s.hisr = true;
    s.history.push("hisr");
    Ok(())
}

/// §4.2 — materialize the iterated tuples into sequence(s) `PA`.
/// Loop-dependent iff an orthogonalization loop condition exists.
pub fn materialize(s: &mut ChainState) -> R {
    if s.materialized.is_some() {
        return illegal("already materialized");
    }
    if let Some(Blocking::FillCutoff) = s.blocked {
        return illegal("fill-cutoff blocking applies after materialization");
    }
    let dependent = s.orth != Orth::None;
    s.materialized = Some(dependent);
    s.history.push(if dependent { "materialize(dep)" } else { "materialize(indep)" });
    Ok(())
}

/// §4.3.2 — structure splitting (AoS → SoA).
pub fn split(s: &mut ChainState) -> R {
    if s.materialized.is_none() {
        return illegal("splitting operates on materialized sequences");
    }
    if s.split {
        return illegal("already split");
    }
    if s.dim_reduced {
        return illegal("split before dimensionality reduction");
    }
    s.split = true;
    s.history.push("split");
    Ok(())
}

/// §4.3.3 — make ℕ* explicit, either padded (single `K = max len`) or
/// exact (`PA_len[i] = len(PA[i])`).
pub fn nstar_materialize(s: &mut ChainState, flavor: NStarMat) -> R {
    if s.materialized != Some(true) {
        return illegal("ℕ* materialization requires loop-dependent materialization");
    }
    if s.nstar.is_some() {
        return illegal("ℕ* already materialized");
    }
    if s.orth == Orth::Diag {
        return illegal("diagonal groups concretize directly (DIA)");
    }
    if flavor == NStarMat::Padded && s.orth != Orth::Row {
        return illegal("padded ℕ* implemented for row orthogonalization");
    }
    s.nstar = Some(flavor);
    s.history.push(match flavor {
        NStarMat::Padded => "nstar(padded)",
        NStarMat::Exact => "nstar(exact)",
    });
    Ok(())
}

/// §4.3.4 — permute the outer loop by decreasing inner length.
pub fn nstar_sort(s: &mut ChainState) -> R {
    if s.materialized != Some(true) {
        return illegal("ℕ* sorting requires loop-dependent materialization");
    }
    if s.sorted {
        return illegal("already sorted");
    }
    if s.dim_reduced {
        return illegal("sorting must precede dimensionality reduction");
    }
    if s.orth != Orth::Row {
        return illegal("ℕ* sorting implemented for row orthogonalization");
    }
    if s.kernel == Kernel::Trsv {
        return illegal("row permutation breaks TrSv forward-substitution order");
    }
    s.sorted = true;
    s.history.push("nstar_sort");
    Ok(())
}

/// §5.2 — post-materialization loop interchange: the slot loop `k`
/// becomes outermost (Fig 3b), changing the grouping of the generated
/// structure (row-major ↔ column-major / ITPACK / JDS direction).
pub fn interchange(s: &mut ChainState) -> R {
    if s.materialized != Some(true) {
        return illegal("interchange operates on the materialized nest");
    }
    if s.interchanged {
        return illegal("already interchanged");
    }
    if s.dim_reduced {
        return illegal("ptr-range loop cannot be interchanged");
    }
    if s.nstar.is_none() {
        return illegal("make ℕ* explicit before interchanging");
    }
    if s.orth != Orth::Row {
        return illegal("interchange implemented for row orthogonalization");
    }
    if s.kernel == Kernel::Trsv {
        return illegal("interchange breaks TrSv dependences");
    }
    s.interchanged = true;
    s.history.push("interchange");
    Ok(())
}

/// §4.3.5 — store nested sequences back to back with a `PA_ptr` array.
pub fn dim_reduce(s: &mut ChainState) -> R {
    if s.materialized != Some(true) {
        return illegal("dimensionality reduction requires nested sequences");
    }
    if s.dim_reduced {
        return illegal("already reduced");
    }
    match s.nstar {
        Some(NStarMat::Exact) => {}
        Some(NStarMat::Padded) => return illegal("padded sequences are rectangular, not jagged"),
        None => return illegal("make ℕ* explicit (exact) first"),
    }
    if s.orth == Orth::Diag {
        return illegal("diagonal groups concretize directly (DIA)");
    }
    s.dim_reduced = true;
    s.history.push("dim_reduce");
    Ok(())
}

/// §5.3 / §6.2.3 — loop blocking. `Tile` partitions both orthogonalized
/// index dimensions before materialization (submatrix blocks → BCSR);
/// `FillCutoff` partitions ℕ* by row fill after materialization (hybrid
/// ELL+COO).
pub fn block(s: &mut ChainState, b: Blocking) -> R {
    if s.blocked.is_some() {
        return illegal("already blocked");
    }
    match b {
        Blocking::Tile { br, bc } => {
            if br == 0 || bc == 0 {
                return illegal("zero block extent");
            }
            if s.orth != Orth::RowCol {
                return illegal("tile blocking requires (row,col) orthogonalization");
            }
            if s.materialized.is_some() {
                return illegal("tile blocking precedes materialization (Fig 4 left)");
            }
            if s.kernel == Kernel::Trsv {
                return illegal("tiled TrSv not generated (dependences)");
            }
        }
        Blocking::RowSlice { s: slice } => {
            if slice == 0 {
                return illegal("zero slice height");
            }
            if s.orth != Orth::Row {
                return illegal("row-slice blocking requires row orthogonalization");
            }
            if s.materialized.is_some() {
                return illegal("row-slice blocking precedes materialization (per-slice padded ℕ*)");
            }
            if s.kernel == Kernel::Trsv {
                return illegal("sliced TrSv not generated (within-slice dependences)");
            }
        }
        Blocking::FillCutoff => {
            if s.orth != Orth::Row {
                return illegal("fill-cutoff blocking requires row orthogonalization");
            }
            if s.materialized != Some(true) {
                return illegal("fill-cutoff blocking partitions materialized ℕ* (Fig 4 right)");
            }
            if s.nstar.is_some() || s.interchanged || s.sorted || s.dim_reduced {
                return illegal("fill-cutoff blocking applies to the plain materialized nest");
            }
        }
    }
    s.blocked = Some(b);
    s.history.push(match b {
        Blocking::Tile { .. } => "block(tile)",
        Blocking::FillCutoff => "block(fill)",
        Blocking::RowSlice { .. } => "block(slice)",
    });
    Ok(())
}

/// A named, boxed transformation step — the unit the search tree
/// composes into chains.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Step {
    Orthogonalize(Orth),
    Localize,
    Hisr,
    Materialize,
    Split,
    NStar(NStarMat),
    NStarSort,
    Interchange,
    DimReduce,
    Block(BlockStep),
}

/// `Blocking` with hashable params for enumeration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockStep {
    Tile2x2,
    Tile3x3,
    Tile4x4,
    FillCutoff,
    RowSlice32,
    RowSlice128,
}

impl BlockStep {
    pub fn to_blocking(self) -> Blocking {
        match self {
            BlockStep::Tile2x2 => Blocking::Tile { br: 2, bc: 2 },
            BlockStep::Tile3x3 => Blocking::Tile { br: 3, bc: 3 },
            BlockStep::Tile4x4 => Blocking::Tile { br: 4, bc: 4 },
            BlockStep::FillCutoff => Blocking::FillCutoff,
            BlockStep::RowSlice32 => Blocking::RowSlice { s: 32 },
            BlockStep::RowSlice128 => Blocking::RowSlice { s: 128 },
        }
    }
}

impl Step {
    pub fn apply(&self, s: &mut ChainState) -> R {
        match *self {
            Step::Orthogonalize(o) => orthogonalize(s, o),
            Step::Localize => localize(s),
            Step::Hisr => hisr(s),
            Step::Materialize => materialize(s),
            Step::Split => split(s),
            Step::NStar(f) => nstar_materialize(s, f),
            Step::NStarSort => nstar_sort(s),
            Step::Interchange => interchange(s),
            Step::DimReduce => dim_reduce(s),
            Step::Block(b) => block(s, b.to_blocking()),
        }
    }

    pub fn name(&self) -> String {
        format!("{self:?}")
    }
}

/// Apply a whole chain, failing on the first illegal step.
pub fn apply_chain(kernel: Kernel, steps: &[Step]) -> Result<ChainState, TransformError> {
    let mut s = ChainState::initial(kernel);
    for st in steps {
        st.apply(&mut s)?;
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(kernel: Kernel, steps: &[Step]) -> Result<ChainState, TransformError> {
        apply_chain(kernel, steps)
    }

    #[test]
    fn fig8_itpack_chain_is_legal() {
        // Fig 8 main path: orthogonalize(row) → materialize → split →
        // padded ℕ* → (concretize → ITPACK after interchange).
        let s = chain(
            Kernel::Spmv,
            &[
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Padded),
                Step::Interchange,
            ],
        )
        .unwrap();
        assert!(s.split && s.interchanged);
        assert_eq!(s.nstar, Some(NStarMat::Padded));
    }

    #[test]
    fn csr_chain_is_legal() {
        let s = chain(
            Kernel::Spmv,
            &[
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce,
            ],
        )
        .unwrap();
        assert!(s.dim_reduced);
    }

    #[test]
    fn jds_chain_is_legal() {
        let s = chain(
            Kernel::Spmv,
            &[
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStarSort,
                Step::NStar(NStarMat::Exact),
                Step::Interchange,
            ],
        )
        .unwrap();
        assert!(s.sorted && s.interchanged);
    }

    #[test]
    fn illegal_orders_rejected() {
        // materialize before orthogonalize is legal (loop-independent),
        // but orthogonalize after materialize is not.
        assert!(chain(Kernel::Spmv, &[Step::Materialize, Step::Orthogonalize(Orth::Row)]).is_err());
        // dim reduce without exact ℕ*
        assert!(chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::Row), Step::Materialize, Step::DimReduce]
        )
        .is_err());
        // padded ℕ* then dim reduce
        assert!(chain(
            Kernel::Spmv,
            &[
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::NStar(NStarMat::Padded),
                Step::DimReduce
            ]
        )
        .is_err());
        // double split
        assert!(chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::Row), Step::Materialize, Step::Split, Step::Split]
        )
        .is_err());
    }

    #[test]
    fn trsv_restrictions() {
        // sorting and interchange break forward substitution
        assert!(chain(
            Kernel::Trsv,
            &[Step::Orthogonalize(Orth::Row), Step::Materialize, Step::NStarSort]
        )
        .is_err());
        assert!(chain(
            Kernel::Trsv,
            &[
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::NStar(NStarMat::Padded),
                Step::Interchange
            ]
        )
        .is_err());
        // but CSR/CSC chains remain legal
        assert!(chain(
            Kernel::Trsv,
            &[
                Step::Orthogonalize(Orth::Col),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce
            ]
        )
        .is_ok());
    }

    #[test]
    fn blocking_legality() {
        // tile requires row+col orthogonalization, pre-materialization
        assert!(chain(Kernel::Spmv, &[Step::Block(BlockStep::Tile2x2)]).is_err());
        assert!(chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::RowCol), Step::Block(BlockStep::Tile3x3), Step::Materialize]
        )
        .is_ok());
        // fill cutoff requires materialized row nest
        assert!(chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::Row), Step::Materialize, Step::Block(BlockStep::FillCutoff)]
        )
        .is_ok());
        assert!(chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::Row), Step::Block(BlockStep::FillCutoff)]
        )
        .is_err());
    }

    #[test]
    fn history_records_chain() {
        let s = chain(
            Kernel::Spmv,
            &[Step::Orthogonalize(Orth::Row), Step::Materialize, Step::Split],
        )
        .unwrap();
        assert_eq!(s.history, vec!["orthogonalize(row)", "materialize(dep)", "split"]);
    }
}
