//! Benchmark substrate: the measurement harness and paper-style table
//! rendering. The actual sweeps live in `coordinator::sweep`; the bench
//! binaries under `rust/benches/` drive them.

pub mod harness;
pub mod tables;
