//! Paper-table drivers and renderers. Each `table*` / `fig*` function
//! regenerates one table or figure of the paper's evaluation (§6.4) from
//! live measurements and returns the formatted report; the CLI, the
//! bench binaries and `examples/e2e_suite.rs` all share these.

use crate::baselines::Kernel;
use crate::coordinator::sweep::{self, Arch, SweepConfig, SweepResult};
use crate::runtime::XlaBackend;
use crate::search::coverage;
use crate::search::plan::PlanSpace;
use crate::search::select;
use crate::search::tree;
use crate::util::rng::Rng;
use crate::util::stats::pct_reduction;

/// Obtain the XLA backend if artifacts are present (never fails hard —
/// the sweep degrades to native-only, as the paper's per-arch tables
/// degrade to the routines that exist).
pub fn try_xla() -> Option<XlaBackend> {
    match XlaBackend::from_default_dir() {
        Ok(b) if !b.manifest.entries.is_empty() => Some(b),
        _ => None,
    }
}

fn fmt_pct(v: f64) -> String {
    format!("{v:5.1}%")
}

/// Render a paper-style reduction table: rows = matrices, columns =
/// library routines; cell = % reduction of the best generated variant
/// vs that library routine. The per-row maximum is wrapped in `**` (the
/// paper's black background) and the minimum in `..` (gray background).
pub fn render_reduction_table(sweep: &SweepResult) -> String {
    let best = sweep.best_gen();
    let nr = sweep.libs.routines.len();
    let mut out = String::new();
    out.push_str(&format!(
        "### {} — {} (reduction of exec time vs best generated variant)\n",
        sweep.kernel.label(),
        sweep.arch.name()
    ));
    out.push_str(&format!("{:<12}", "matrix"));
    for r in &sweep.libs.routines {
        out.push_str(&format!(" {:>12}", r));
    }
    out.push('\n');
    for (mi, m) in sweep.libs.matrices.iter().enumerate() {
        let cells: Vec<f64> =
            (0..nr).map(|ri| pct_reduction(best[mi], sweep.libs.times[ri][mi])).collect();
        let max = cells.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = cells.iter().cloned().fold(f64::INFINITY, f64::min);
        out.push_str(&format!("{m:<12}"));
        for &c in &cells {
            let s = if (c - max).abs() < 1e-12 {
                format!("**{}**", fmt_pct(c))
            } else if (c - min).abs() < 1e-12 {
                format!("..{}..", fmt_pct(c))
            } else {
                format!("  {}  ", fmt_pct(c))
            };
            out.push_str(&format!(" {s:>12}"));
        }
        out.push('\n');
    }
    // Summary line: reduction vs the *best* library routine per matrix.
    let best_lib = sweep.libs.best_per_matrix(None);
    let vs_best: Vec<f64> =
        (0..best.len()).map(|mi| pct_reduction(best[mi], best_lib[mi])).collect();
    let max_i = vs_best
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    out.push_str(&format!(
        "vs best library routine per matrix: max {} ({}), mean {}\n",
        fmt_pct(vs_best[max_i]),
        sweep.libs.matrices[max_i],
        fmt_pct(vs_best.iter().sum::<f64>() / vs_best.len() as f64)
    ));
    out
}

/// Run one kernel × arch sweep.
pub fn run_sweep(
    kernel: Kernel,
    arch: Arch,
    cfg: &SweepConfig,
    xla: Option<&XlaBackend>,
) -> SweepResult {
    sweep::run(kernel, arch, cfg, xla)
}

/// Tables 1(a)/1(b): SpMV on both architectures.
pub fn table1(cfg: &SweepConfig, xla: Option<&XlaBackend>) -> (String, SweepResult, SweepResult) {
    let a = run_sweep(Kernel::Spmv, Arch::HostSmall, cfg, xla);
    let b = run_sweep(Kernel::Spmv, Arch::HostLarge, cfg, xla);
    let mut out = String::from("## Table 1 — sparse matrix times vector multiplication\n\n(a)\n");
    out.push_str(&render_reduction_table(&a));
    out.push_str("\n(b)\n");
    out.push_str(&render_reduction_table(&b));
    (out, a, b)
}

/// Table 2: SpMM (k dense columns) on both architectures.
pub fn table2(cfg: &SweepConfig, xla: Option<&XlaBackend>) -> (String, SweepResult, SweepResult) {
    let a = run_sweep(Kernel::Spmm, Arch::HostSmall, cfg, xla);
    let b = run_sweep(Kernel::Spmm, Arch::HostLarge, cfg, xla);
    let mut out = format!(
        "## Table 2 — sparse matrix times matrix multiplication (k = {})\n\n",
        cfg.spmm_k
    );
    out.push_str(&render_reduction_table(&a));
    out.push('\n');
    out.push_str(&render_reduction_table(&b));
    (out, a, b)
}

/// Table 3: TrSv on both architectures.
pub fn table3(cfg: &SweepConfig, xla: Option<&XlaBackend>) -> (String, SweepResult, SweepResult) {
    let a = run_sweep(Kernel::Trsv, Arch::HostSmall, cfg, xla);
    let b = run_sweep(Kernel::Trsv, Arch::HostLarge, cfg, xla);
    let mut out = String::from("## Table 3 — sparse triangular solve (unit lower)\n\n");
    out.push_str(&render_reduction_table(&a));
    out.push('\n');
    out.push_str(&render_reduction_table(&b));
    (out, a, b)
}

/// Table 4: coverage of the library collection for t% ∈ {10..50},
/// optimum taken within the library collection (can one library routine
/// serve all matrices?).
pub fn table4(sweeps: &[&SweepResult]) -> String {
    let ts = [10.0, 20.0, 30.0, 40.0, 50.0];
    let mut out = String::from("## Table 4 — library-collection coverage vs t%\n");
    out.push_str(&format!("{:<22}", "kernel/arch"));
    for t in ts {
        out.push_str(&format!(" {:>6.0}%", t));
    }
    out.push_str("  min t% for 100%\n");
    for s in sweeps {
        let best = s.libs.best_per_matrix(None);
        out.push_str(&format!("{:<22}", format!("{} {:?}", s.kernel.label(), s.arch)));
        for t in ts {
            let c = coverage::coverage(&s.libs, &best, None, t);
            out.push_str(&format!(" {:>6.0}%", c * 100.0));
        }
        let mt = coverage::min_t_for_full_coverage(&s.libs, &best, None, 400.0);
        out.push_str(&format!(
            "  {}\n",
            mt.map(|t| format!("{t:.0}%")).unwrap_or_else(|| ">400%".into())
        ));
    }
    out
}

/// Table 5: (a) min average reduction of library routines vs the optimal
/// (combined) routine; (b) worst average reduction of the §6.4.5
/// auto-selected all-round variant (k = 4, t = 2%).
pub fn table5(sweeps: &[&SweepResult], seed: u64) -> String {
    let mut out = String::from(
        "## Table 5 — (a) best library avg distance vs (b) worst auto-selected variant\n",
    );
    out.push_str(&format!(
        "{:<22} {:>10} {:>10} {:>12}\n",
        "kernel/arch", "(a) lib", "(b) sel", "candidates"
    ));
    for s in sweeps {
        let all = s.combined();
        let best = all.best_per_matrix(None);
        let a = select::min_avg_reduction(&all, &best, &s.lib_indices());
        let mut rng = Rng::new(seed);
        let sel = select::select_allround(&all, &best, &s.gen_indices(), 4, 2.0, &mut rng);
        out.push_str(&format!(
            "{:<22} {:>9.1}% {:>9.1}% {:>12}\n",
            format!("{} {:?}", s.kernel.label(), s.arch),
            a,
            sel.worst_avg_reduction,
            sel.candidates.len()
        ));
    }
    out
}

/// Figure 11: coverage curves vs t% for (left) Blaze-only, (right) all
/// libraries, plus the generated collection — optimum over the combined
/// collection. CSV-ish series for plotting.
pub fn fig11(s: &SweepResult) -> String {
    let all = s.combined();
    let best = all.best_per_matrix(None);
    let blaze_idx: Vec<usize> = all
        .routines
        .iter()
        .enumerate()
        .filter(|(_, n)| n.starts_with("Blaze"))
        .map(|(i, _)| i)
        .collect();
    let lib_idx = s.lib_indices();
    let gen_idx = s.gen_indices();
    let ts: Vec<f64> = (0..=50).map(|t| t as f64).collect();
    let mut out = format!(
        "## Figure 11 — coverage vs t% ({} {:?}); optimum = combined collection\n",
        s.kernel.label(),
        s.arch
    );
    out.push_str("t%, blaze, all_libraries, generated\n");
    for &t in &ts {
        let cb = coverage::coverage(&all, &best, Some(&blaze_idx), t);
        let cl = coverage::coverage(&all, &best, Some(&lib_idx), t);
        let cg = coverage::coverage(&all, &best, Some(&gen_idx), t);
        out.push_str(&format!("{t:.0}, {:.2}, {:.2}, {:.2}\n", cb * 100.0, cl * 100.0, cg * 100.0));
    }
    out
}

/// Figure 10: the transformation tree report.
pub fn fig10() -> String {
    let mut out =
        String::from("## Figure 10 — transformation tree of sparse matrix times k vectors\n");
    let space = PlanSpace::serial_only();
    for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
        let t = tree::enumerate(kernel, &space);
        out.push_str(&format!(
            "\n{}: {} concretizable chains, {} deduped executables, {} distinct data structures, {} IR nodes explored\n",
            kernel.label(),
            t.chains_concretized,
            t.plans.len(),
            t.distinct_layouts,
            t.nodes_explored
        ));
        for (layout, n) in tree::layout_histogram(&t) {
            out.push_str(&format!("  {layout:<40} {n} variant(s)\n"));
        }
    }
    out.push_str("\n(paper: 130 executables / 25 data structures for SpMM×k; our tree\n dedups structurally identical executables — same order of magnitude.)\n");
    out
}

/// Planner report: per-matrix best measured (layout, traversal,
/// schedule) triple, the cost model's first pick, and the top-1
/// rank-agreement summary — the human-readable face of the
/// predict→measure pipeline (`BENCH_spmv.json` carries the machine-
/// readable version).
pub fn best_triples_report(s: &SweepResult) -> String {
    let mut out = format!(
        "## Best plan per matrix — {} {:?} (predict\u{2192}measure)\n",
        s.kernel.label(),
        s.arch
    );
    out.push_str(&format!(
        "{:<12} {:<28} {:<28} {:>10}\n",
        "matrix", "measured best", "predicted best", "secs"
    ));
    for (mi, t) in s.best_triples().iter().enumerate() {
        let pb = s.predicted_best(mi);
        let mark = if pb == t.plan_index { "" } else { " *" };
        out.push_str(&format!(
            "{:<12} {:<28} {:<28} {:>10.3e}{}\n",
            t.matrix,
            t.plan_id,
            s.plans[pb].id,
            t.secs,
            mark
        ));
    }
    let (matches, total) = s.rank_agreement();
    out.push_str(&format!(
        "cost-model top-1 agreement: {matches}/{total} matrices (* = model missed)\n"
    ));
    out
}

/// Persist a report section (appended) — used to assemble EXPERIMENTS.md.
pub fn record(path: &str, section: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{section}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table1_renders() {
        let cfg = SweepConfig::quick();
        let (txt, a, b) = table1(&cfg, None);
        assert!(txt.contains("Table 1"));
        assert!(txt.contains("Blaze CRS"));
        assert!(txt.contains("**")); // per-row max marked
        assert_eq!(a.libs.matrices, b.libs.matrices);
    }

    #[test]
    fn fig10_report_mentions_formats() {
        let txt = fig10();
        assert!(txt.contains("distinct data structures"));
        assert!(txt.contains("Csr"));
        assert!(txt.contains("Jds"));
    }

    #[test]
    fn table4_and_5_and_fig11_render() {
        let cfg = SweepConfig::quick();
        let a = run_sweep(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        let t4 = table4(&[&a]);
        assert!(t4.contains("min t% for 100%"));
        let t5 = table5(&[&a], 42);
        assert!(t5.contains("(a) lib"));
        let f11 = fig11(&a);
        assert!(f11.lines().count() > 50);
        assert!(f11.contains("t%, blaze, all_libraries, generated"));
        let bt = best_triples_report(&a);
        assert!(bt.contains("top-1 agreement"));
        assert!(bt.contains("measured best"));
        assert_eq!(bt.lines().count(), 2 + a.gens.matrices.len() + 1);
    }
}
