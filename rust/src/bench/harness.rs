//! Measurement harness (criterion is unavailable offline; DESIGN.md §5).
//!
//! Protocol, following the paper's §6.4.1 ("the computation performed by
//! each variant or library is repeated 10 times"): auto-calibrate an
//! inner iteration count so one sample lasts ≥ `min_sample`, warm up,
//! take `repeats` samples, summarize with the median.

use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Outer samples (the paper uses 10).
    pub repeats: usize,
    /// Minimum duration of one calibrated sample.
    pub min_sample_secs: f64,
    /// Warmup samples discarded before measuring.
    pub warmup: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { repeats: 10, min_sample_secs: 2e-3, warmup: 2 }
    }
}

impl BenchConfig {
    /// Fast config for tests / smoke runs.
    pub fn quick() -> Self {
        BenchConfig { repeats: 3, min_sample_secs: 2e-4, warmup: 1 }
    }

    pub fn from_env() -> Self {
        let mut c = BenchConfig::default();
        if let Ok(r) = std::env::var("FORELEM_BENCH_REPEATS") {
            if let Ok(r) = r.parse() {
                c.repeats = r;
            }
        }
        if let Ok(s) = std::env::var("FORELEM_BENCH_MIN_SAMPLE") {
            if let Ok(s) = s.parse() {
                c.min_sample_secs = s;
            }
        }
        c
    }
}

/// Time `f` under the protocol; returns per-invocation seconds.
pub fn time_fn<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Summary {
    // Calibrate inner iterations.
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= cfg.min_sample_secs || iters >= 1 << 24 {
            break;
        }
        // Aim slightly past the floor to limit re-calibration rounds.
        let scale = (cfg.min_sample_secs / dt.max(1e-9) * 1.3).ceil() as usize;
        iters = (iters * scale.max(2)).min(1 << 24);
    }
    for _ in 0..cfg.warmup {
        for _ in 0..iters {
            f();
        }
    }
    let mut samples = Vec::with_capacity(cfg.repeats);
    for _ in 0..cfg.repeats {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    Summary::of(&samples)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig::quick();
        let mut acc = 0.0f64;
        let s = time_fn(&cfg, || {
            for i in 0..1000 {
                acc += (i as f64).sqrt();
            }
            black_box(acc);
        });
        assert!(s.median > 0.0);
        assert_eq!(s.n, cfg.repeats);
    }

    #[test]
    fn longer_work_measures_longer() {
        let cfg = BenchConfig::quick();
        let mut sink = 0.0f64;
        let short = time_fn(&cfg, || {
            for i in 0..500 {
                sink += (i as f64).sqrt();
            }
            black_box(sink);
        });
        let long = time_fn(&cfg, || {
            for i in 0..50_000 {
                sink += (i as f64).sqrt();
            }
            black_box(sink);
        });
        assert!(long.median > short.median * 5.0, "short {} long {}", short.median, long.median);
    }
}
