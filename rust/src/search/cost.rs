//! The analytic cost model of the predict→measure planner (stage 1 of
//! the pipeline; see `search::plan` for the pipeline overview).
//!
//! Given a concretization triple (`concretize::Plan`), a matrix summary
//! ([`MatrixStats`]) and architecture parameters ([`CostParams`]), the
//! model predicts an execution time in seconds from first principles:
//!
//! * **streamed bytes** — the stored structure plus output traffic,
//!   layout-specific (padded formats stream their padding; plane-wise
//!   traversals re-stream `y` once per plane; DIA streams dense
//!   diagonal planes),
//! * **gathered bytes** — the random `x` (or scattered `y`) accesses,
//!   charged at gather bandwidth only for the fraction of the working
//!   set that exceeds the last-level cache (banded matrices get their
//!   locality back through `avg_bandwidth`),
//! * **flops** — `2 · slots · k`, rooflined against the memory time,
//! * **loop overhead** — per-row/plane/diagonal header cost (what makes
//!   branch-free padded traversals win on perfectly uniform matrices),
//! * **schedule terms** — parallel speedup limited by grain, row-length
//!   imbalance (`row_cv`) and per-invocation thread spawn cost; tiled
//!   schedules trade the gather penalty for per-band split/`y` traffic;
//!   level-scheduled TrSv pays one spin barrier per supernoded wave.
//!
//! # The fittable feature form
//!
//! Since the calibration refactor the model is *linear in its
//! parameters*: [`features`] maps a plan + statistics to a fixed-order
//! [`FeatureVec`] (streamed bytes, gathered bytes, flops, loop headers,
//! spawn count, barrier-wave count, imbalance bytes, gather-lane ops,
//! cross-socket remote bytes) and the predicted time is the dot product
//! with
//! [`CostParams::weights`]. All
//! nonlinearity — the L2 miss split, the memory/flop roofline, the
//! effective parallel speedup — is resolved *inside the extractor*
//! against the structural machine shape (`l2_bytes`, `threads`) and the
//! reference weights, so a `(FeatureVec, measured_time)` sample archive
//! can be refit by non-negative least squares (`search::calibrate`)
//! without touching this module. The hand-set `host_small`/`host_large`
//! bandwidth numbers survive as the *seed* weight vectors.
//!
//! The point is *ranking*, not absolute accuracy: the sweep measures
//! the top of the predicted order and reports predicted-vs-measured
//! agreement (`BENCH_spmv.json`) so the model is auditable across PRs.

use crate::baselines::Kernel;
use crate::concretize::{Layout, Plan as ExecPlan, Schedule, Traversal};
use crate::matrix::MatrixStats;
use crate::storage::CooOrder;

/// Number of entries in a [`FeatureVec`] / weight vector.
pub const N_FEATURES: usize = 9;

/// Fixed feature order — the contract between this extractor, the
/// sample archive in `BENCH_*.json`, and `search::calibrate`'s fit.
/// Index `i` of every persisted weight/feature array means
/// `FEATURE_NAMES[i]`, forever; new features are appended, never
/// reordered.
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "stream_bytes",   // sequentially streamed bytes (incl. cache-hit gathers)
    "gather_bytes",   // cache-missing randomly gathered bytes
    "flops",          // floating-point operations (when compute-bound)
    "loop_headers",   // inner-loop headers executed
    "spawns",         // scoped threads spawned per invocation
    "syncs",          // barrier waves × threads (level-scheduled TrSv)
    "imbalance_bytes", // row-cv-weighted parallel byte volume (seed weight 0)
    "gather_lanes",   // hardware gather ops of a wide plan (seed weight 0)
    "remote_bytes",   // cross-socket share of parallel bytes (seed weight 0)
];

pub const F_STREAM: usize = 0;
pub const F_GATHER: usize = 1;
pub const F_FLOPS: usize = 2;
pub const F_HEADERS: usize = 3;
pub const F_SPAWNS: usize = 4;
pub const F_SYNCS: usize = 5;
pub const F_IMBALANCE: usize = 6;
pub const F_GATHER_LANES: usize = 7;
pub const F_REMOTE: usize = 8;

/// A plan's footprint on one matrix in the fixed [`FEATURE_NAMES`]
/// order. Predicted seconds = `dot(features, CostParams::weights)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FeatureVec(pub [f64; N_FEATURES]);

impl FeatureVec {
    pub fn zero() -> Self {
        FeatureVec([0.0; N_FEATURES])
    }

    /// Fixed-order dot product with a weight vector — deterministic
    /// summation order, index 0 first.
    pub fn dot(&self, w: &[f64; N_FEATURES]) -> f64 {
        let mut acc = 0.0;
        for (f, wi) in self.0.iter().zip(w.iter()) {
            acc += f * wi;
        }
        acc
    }
}

/// Architecture parameters of the cost model — the planner-facing
/// summary of a `coordinator::sweep::Arch`, split into the *structural*
/// machine shape (`l2_bytes`, `threads` — resolved inside the feature
/// extractor) and the *fitted* linear weight vector (`weights`, in the
/// [`FEATURE_NAMES`] order: seconds per byte / flop / header / spawn /
/// sync / imbalance-byte).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Last-level cache a working set must fit in to gather cheaply
    /// (structural — not fitted).
    pub l2_bytes: f64,
    /// Worker threads the architecture exposes to parallel schedules
    /// (structural — not fitted).
    pub threads: usize,
    /// Vector register width in bytes (structural — not fitted): caps
    /// the effective lane count of a wide plan (`lanes ≤ vector_bytes /
    /// 8` f64 lanes actually retire per step). 32 = AVX2.
    pub vector_bytes: f64,
    /// NUMA nodes the parallel bytes of a schedule are spread over
    /// (structural — not fitted, like `vector_bytes`): with `S` sockets
    /// a fraction `(S-1)/S` of a parallel schedule's byte traffic is
    /// charged to the `remote_bytes` feature. 1 (the seed value and
    /// every single-node machine) zeroes the feature exactly, so the
    /// dimension is free until `runtime::topology` detects real nodes
    /// *and* calibration fits it a nonzero price.
    pub sockets: usize,
    /// The fitted coefficients, `FEATURE_NAMES` order.
    pub weights: [f64; N_FEATURES],
}

impl CostParams {
    /// Build a parameter vector from bandwidth-style rates — how the
    /// seed machines are specified. `imbalance` seeds at 0 so the seed
    /// predictions equal the pre-calibration closed formula.
    #[allow(clippy::too_many_arguments)]
    pub fn from_rates(
        l2_bytes: f64,
        threads: usize,
        stream_bw: f64,
        gather_bw: f64,
        flop_rate: f64,
        loop_overhead: f64,
        spawn_overhead: f64,
        sync_overhead: f64,
    ) -> Self {
        CostParams {
            l2_bytes,
            threads: threads.max(1),
            vector_bytes: 32.0,
            sockets: 1,
            weights: [
                1.0 / stream_bw,
                1.0 / gather_bw,
                1.0 / flop_rate,
                loop_overhead,
                spawn_overhead,
                sync_overhead,
                0.0,
                0.0,
                0.0,
            ],
        }
    }

    /// The paper-protocol single-core machine (Xeon 5150 stand-in).
    pub fn host_small() -> Self {
        CostParams::from_rates(4e6, 1, 8e9, 1.5e9, 4e9, 1.5e-9, 2.5e-5, 4e-7)
    }

    /// The modern multi-core machine (Xeon E5 stand-in).
    pub fn host_large(threads: usize) -> Self {
        CostParams::from_rates(8e6, threads.max(1), 20e9, 4e9, 8e9, 1.0e-9, 2.5e-5, 3e-7)
    }

    /// `self` with the weight vector replaced (what a calibration fit
    /// returns — the structural shape is kept).
    pub fn with_weights(mut self, weights: [f64; N_FEATURES]) -> Self {
        self.weights = weights;
        self
    }

    /// `self` with the structural socket count replaced (what the sweep
    /// applies from `runtime::topology::sockets()` — never persisted,
    /// never fitted).
    pub fn with_sockets(mut self, sockets: usize) -> Self {
        self.sockets = sockets.max(1);
        self
    }
}

/// Resource descriptor of a plan on a matrix — the analytic footprint
/// the cost model integrates into a predicted time.
#[derive(Clone, Copy, Debug)]
pub struct Resources {
    /// Sequentially streamed bytes per invocation (structure + output).
    pub streamed_bytes: f64,
    /// The stored-structure part of `streamed_bytes` alone — what a
    /// B-panel SpMM sweep re-streams once per panel.
    pub structure_bytes: f64,
    /// Randomly gathered bytes per invocation (`x` reads / `y` scatter).
    pub gathered_bytes: f64,
    /// Working set the gathers revisit (what wants to be L2-resident).
    pub gather_working_set: f64,
    /// Floating-point operations per invocation.
    pub flops: f64,
    /// Inner-loop headers executed (rows / planes / diagonals / blocks).
    pub loop_headers: f64,
    /// Independent output partitions a parallel schedule can exploit.
    pub parallel_grain: usize,
}

/// Layout-specific serial footprint (before schedule terms).
fn layout_resources(
    kernel: Kernel,
    dense_k: usize,
    exec: &ExecPlan,
    stats: &MatrixStats,
) -> Resources {
    let n = stats.nrows.max(1) as f64;
    let nc = stats.ncols.max(1) as f64;
    let nnz = stats.nnz as f64;
    let row_max = stats.row_max as f64;
    let kf = if kernel == Kernel::Spmm { dense_k.max(1) as f64 } else { 1.0 };

    // Defaults for the row-oriented formats: one output pass, random x.
    let mut out_bytes = 16.0 * n * kf;
    let mut x_stream = 0.0; // sequential x traffic (scatter & DIA shapes)
    let mut gather_ws = nc * 8.0 * kf;
    let mut scatter = false; // y is the randomly-accessed side instead of x

    let (stored, slots, headers, grain): (f64, f64, f64, usize) = match exec.layout {
        Layout::CooAos(order) | Layout::CooSoa(order) => {
            if order != CooOrder::RowMajor {
                scatter = true;
            }
            (nnz * 16.0, nnz, 1.0, 1)
        }
        Layout::Csr => (nnz * 12.0 + (n + 1.0) * 4.0, nnz, n, stats.nrows),
        Layout::CsrAos => (nnz * 16.0 + (n + 1.0) * 4.0, nnz, n, stats.nrows),
        Layout::Csc => {
            scatter = true;
            x_stream = nc * 8.0 * kf;
            (nnz * 12.0 + (nc + 1.0) * 4.0, nnz, nc, 1)
        }
        Layout::CscAos => {
            scatter = true;
            x_stream = nc * 8.0 * kf;
            (nnz * 16.0 + (nc + 1.0) * 4.0, nnz, nc, 1)
        }
        Layout::Ell(_) => {
            let pad_slots = (n * row_max - nnz).max(0.0);
            match exec.traversal {
                // Branch-free: every slot (padding included) is visited.
                Traversal::RowWisePadded => {
                    (n * row_max * 12.0, n * row_max, n * 0.25, stats.nrows)
                }
                // Plane-wise (ITPACK): all slots visited, `y` re-streamed
                // once per plane.
                Traversal::PlaneWise => {
                    out_bytes = 16.0 * n * kf * row_max.max(1.0);
                    (n * row_max * 12.0, n * row_max, row_max, stats.nrows)
                }
                // Exact-length row-wise: only real entries are visited,
                // but the padded planes still waste part of each cache
                // line.
                _ => (nnz * 12.0 + n * 4.0 + pad_slots * 3.0, nnz, n, stats.nrows),
            }
        }
        Layout::Jds { permuted } => {
            // Diagonal-major accumulation re-reads/writes the permuted
            // output once per element, plus the final scatter pass.
            out_bytes = 16.0 * nnz * kf + 24.0 * n * kf;
            let lists = if permuted { n * 4.0 } else { nnz * 4.0 };
            let grain = if permuted { stats.nrows } else { 1 };
            (nnz * 12.0 + row_max * 8.0 + lists, nnz, row_max.max(1.0), grain)
        }
        Layout::Bcsr { br, bc } => {
            // Fill-in estimate: scattered matrices pay close to the full
            // block, clustered (dense) ones close to none.
            let cells = (br * bc) as f64;
            let fill = 1.0 + (cells - 1.0) * (1.0 - stats.density.min(1.0)) * 0.2;
            let slots = (nnz * fill).min(n * nc);
            let nblocks = slots / cells;
            let stored = slots * 8.0 + nblocks * 4.0 + (n / br as f64 + 1.0) * 4.0;
            (stored, slots, nblocks + n / br as f64, stats.nrows.div_ceil(br))
        }
        Layout::HybridEllCoo => {
            // ELL head cut at the mean width + COO tail.
            let slots = nnz * 1.15;
            (slots * 12.0 + n * 4.0, slots, n + 1.0, stats.nrows)
        }
        Layout::Sell { s } => {
            // Each slice pads to its own width ≈ mean + σ/2.
            let pad = (n * stats.row_var.max(0.0).sqrt() * 0.5)
                .min((n * row_max - nnz).max(0.0));
            let slots = nnz + pad;
            let nslices = n / s as f64 + 1.0;
            (slots * 12.0 + nslices * 8.0 + n * 4.0, slots, nslices + slots / s as f64, {
                stats.nrows.div_ceil(s)
            })
        }
        Layout::SellSigma { s, sigma } => {
            // Rows sorted by length within σ windows before slicing:
            // slice widths track the local maximum, so the padding
            // collapses to a sliver of plain SELL's. The output is
            // scattered through the window permutation (bounded by σ,
            // so still near-streamed); perm + row_len lists are the
            // extra stored arrays. Slice-aligned windows are the
            // parallel partition units (`schedule_legal` mirrors the
            // same σ % s == 0 condition).
            let pad = (n * stats.row_var.max(0.0).sqrt() * 0.15)
                .min((n * row_max - nnz).max(0.0));
            let slots = nnz + pad;
            let nslices = n / s as f64 + 1.0;
            let grain = if sigma % s == 0 { stats.nrows.div_ceil(sigma) } else { 1 };
            (slots * 12.0 + nslices * 8.0 + n * 8.0, slots, nslices + slots / s as f64, grain)
        }
        Layout::Dia => {
            let ndiags = (2.0 * stats.bandwidth as f64 + 1.0).min(n + nc - 1.0).max(1.0);
            // Dense diagonal planes; x and y are both streamed per plane.
            out_bytes = 16.0 * n * kf * ndiags;
            x_stream = 8.0 * n * kf * ndiags;
            gather_ws = 0.0;
            (ndiags * n * 8.0 + ndiags * 4.0, ndiags * n, ndiags, 1)
        }
    };

    // Random side: row-oriented formats gather x (one B row of k·8
    // bytes per visited slot for SpMM); scatter shapes gather y
    // read+write instead. Banded matrices keep their gathers local.
    let (gathered, ws) = if gather_ws == 0.0 {
        (0.0, 0.0)
    } else if scatter {
        (slots * 16.0 * kf, n * 8.0 * kf)
    } else {
        let locality = (2.0 * stats.avg_bandwidth * 8.0 * kf + 64.0).min(gather_ws);
        (slots * 8.0 * kf, locality)
    };

    Resources {
        streamed_bytes: stored + out_bytes + x_stream,
        structure_bytes: stored,
        gathered_bytes: gathered,
        gather_working_set: ws,
        flops: 2.0 * slots * kf,
        loop_headers: headers,
        parallel_grain: grain.max(1),
    }
}

/// Full resource descriptor of a plan (schedule-aware). Tiled SpMV
/// adds its per-band split traffic and shrinks the gather working set
/// to one `x` band; tiled SpMM re-streams the stored structure once
/// per B panel in exchange for shrinking the gathered B-row granule
/// (and working set) to the panel width.
pub fn resources(
    kernel: Kernel,
    dense_k: usize,
    exec: &ExecPlan,
    stats: &MatrixStats,
) -> Resources {
    let mut r = layout_resources(kernel, dense_k, exec, stats);
    let n = stats.nrows.max(1) as f64;
    let nc = stats.ncols.max(1) as f64;
    if let Schedule::Tiled { x_block } | Schedule::ParallelTiled { x_block, .. } = exec.schedule {
        match kernel {
            Kernel::Spmv => {
                let nbands = (nc / x_block.max(1) as f64).ceil().max(1.0);
                // Each band re-streams the split row and the partial
                // sums, but the gather working set shrinks to one x
                // band.
                r.streamed_bytes += nbands * n * (4.0 + 16.0);
                r.gather_working_set = r.gather_working_set.min(x_block as f64 * 8.0);
            }
            Kernel::Spmm => {
                let k = dense_k.max(1);
                let panel = crate::concretize::spmm_panel_cols(x_block, k);
                let npanels = (k as f64 / panel as f64).ceil().max(1.0);
                r.streamed_bytes += r.structure_bytes * (npanels - 1.0);
                r.loop_headers *= npanels;
                r.gather_working_set =
                    r.gather_working_set.min(nc * 8.0 * panel as f64);
            }
            Kernel::Trsv => {}
        }
    }
    r
}

/// Extract the fixed-order feature vector of a plan on a matrix — the
/// fittable half of the model. `p` supplies the *structural* machine
/// shape (`l2_bytes`, `threads`) and the reference weights the
/// extractor resolves the nonlinearity against:
///
/// * the L2 miss fraction splits the gathered bytes between the stream
///   and gather entries,
/// * the memory/flop roofline keeps only the dominant side's entries,
/// * parallel schedules pre-divide the work entries by the effective
///   speedup (thread cap × grain cap × `row_cv` efficiency) and record
///   spawn / barrier-wave counts,
/// * the level-scheduled TrSv charges one barrier wave per *supernoded*
///   wave (`MatrixStats::sync_waves`, not raw `dep_levels` — narrow
///   adjacent levels merge into one wave in `kernels::levels`).
///
/// The `imbalance_bytes` entry carries `row_cv × parallel byte volume`
/// with a zero seed weight — a refit can learn a linear imbalance
/// penalty without perturbing seed predictions.
///
/// Seed-identity scope: SpMV/SpMM predictions (and serial TrSv)
/// reproduce the pre-refactor closed formula under the seed weights
/// *up to floating-point reassociation* — the stream-charged byte
/// terms are pre-summed into one feature and the bandwidths applied
/// as reciprocal weights (`x * (1/bw)` instead of `x / bw`), which can
/// move the last ulp; the same formula, bracketed differently, so
/// rankings are unchanged except for sub-ulp ties. The **parallel
/// TrSv** arm intentionally changed alongside the supernoding
/// satellite: it now carries the same ×1.2 dependence stall factor as
/// the serial solve (the supernoded executor runs narrow runs
/// serially, so the stall does not vanish under the level schedule)
/// and charges `sync_waves` instead of per-level barriers.
pub fn features(
    kernel: Kernel,
    dense_k: usize,
    exec: &ExecPlan,
    stats: &MatrixStats,
    p: &CostParams,
) -> FeatureVec {
    let r = resources(kernel, dense_k, exec, stats);

    // Gather: the fraction of accesses whose working set spills past L2
    // pays gather bandwidth; the rest (and the compulsory first touch)
    // streams.
    let ws = r.gather_working_set;
    let miss = if ws > p.l2_bytes { ((ws - p.l2_bytes) / ws).clamp(0.0, 1.0) } else { 0.0 };
    let stream_units = r.streamed_bytes + r.gathered_bytes * (1.0 - miss) + ws;
    let gather_units = r.gathered_bytes * miss;

    // Lane axis: a wide plan retires `eff_lanes` elements per flop /
    // header step (capped by the register width — an 8-lane plan on a
    // 4-lane machine double-pumps), and issues one hardware gather per
    // lane group. The gather count lands in the appended `gather_lanes`
    // entry with a zero seed weight, so seed rankings see only the
    // flop/header saving and a refit learns the per-machine gather
    // cost. Scalar plans (`lanes == 1`) divide by exactly 1.0 and carry
    // a zero lane entry — bit-identical to the pre-lane extractor.
    let lanes = exec.lanes.max(1) as f64;
    let eff_lanes = lanes.min((p.vector_bytes / 8.0).max(1.0));
    let lane_units = if exec.lanes > 1 { r.gathered_bytes / 8.0 / lanes } else { 0.0 };

    // Roofline: memory-bound keeps the byte entries, compute-bound the
    // flop entry — resolved against the reference weights so the dot
    // product reproduces `max(mem_time, flop_time)`.
    let mem_time = stream_units * p.weights[F_STREAM] + gather_units * p.weights[F_GATHER];
    let flop_time = r.flops / eff_lanes * p.weights[F_FLOPS];
    let (su, gu, fu) = if flop_time > mem_time {
        (0.0, 0.0, r.flops / eff_lanes)
    } else {
        (stream_units, gather_units, 0.0)
    };
    let hu = r.loop_headers / eff_lanes;

    let mut f = [0.0; N_FEATURES];
    match exec.schedule {
        Schedule::Serial | Schedule::Tiled { .. } => {
            let dep = if kernel == Kernel::Trsv { 1.2 } else { 1.0 };
            f[F_STREAM] = su * dep;
            f[F_GATHER] = gu * dep;
            f[F_FLOPS] = fu * dep;
            f[F_HEADERS] = hu * dep;
            f[F_GATHER_LANES] = lane_units * dep;
        }
        Schedule::Parallel { threads } if kernel == Kernel::Trsv => {
            // Level-scheduled solve: the speedup is capped by the mean
            // level width (`nrows / dep_levels`) and every supernoded
            // wave pays one spin-barrier sync — a banded matrix with
            // its near-serial chain collapses to few waves but also to
            // no parallelism (the dependence stall factor stays).
            let t = threads.max(1);
            let eff_threads =
                (t.min(p.threads.max(1)) as f64).min(stats.level_width()).max(1.0);
            let eff = 0.9 / (1.0 + stats.row_cv() * 0.25);
            let inv = 1.2 / (eff_threads * eff).max(1.0);
            f[F_STREAM] = su * inv;
            f[F_GATHER] = gu * inv;
            f[F_FLOPS] = fu * inv;
            f[F_HEADERS] = hu * inv;
            f[F_SPAWNS] = t as f64;
            f[F_SYNCS] = stats.sync_waves as f64 * t as f64;
            f[F_IMBALANCE] = stats.row_cv() * (su + gu) * inv;
            f[F_GATHER_LANES] = lane_units * inv;
            f[F_REMOTE] = remote_share(p) * (su + gu) * inv;
        }
        Schedule::Parallel { threads } | Schedule::ParallelTiled { threads, .. } => {
            let t = threads.max(1);
            let eff_threads = t.min(p.threads.max(1)).min(r.parallel_grain) as f64;
            // Row-length imbalance erodes the speedup even with
            // nnz-balanced ranges (one huge row caps the partition).
            let eff = 0.9 / (1.0 + stats.row_cv() * 0.25);
            let inv = 1.0 / (eff_threads * eff).max(1.0);
            f[F_STREAM] = su * inv;
            f[F_GATHER] = gu * inv;
            f[F_FLOPS] = fu * inv;
            f[F_HEADERS] = hu * inv;
            f[F_SPAWNS] = t as f64;
            f[F_IMBALANCE] = stats.row_cv() * (su + gu) * inv;
            f[F_GATHER_LANES] = lane_units * inv;
            f[F_REMOTE] = remote_share(p) * (su + gu) * inv;
        }
    }
    FeatureVec(f)
}

/// Cross-socket fraction of a parallel schedule's byte traffic: with
/// `S` NUMA nodes and node-major worker pinning, a uniformly spread
/// partition reads `(S-1)/S` of its bytes from a remote node unless the
/// first-touch pass placed the pages (the fitted weight decides how
/// much that costs — and whether placement recovered it). Exactly zero
/// on every single-node machine, so serial plans and single-socket CI
/// carry a zero entry bit-identical to the pre-NUMA extractor.
fn remote_share(p: &CostParams) -> f64 {
    let s = p.sockets.max(1) as f64;
    (s - 1.0) / s
}

/// Predict the execution time (seconds) of one invocation of `exec` on
/// a matrix with statistics `stats`, on architecture `p`: the dot
/// product of the extracted [`FeatureVec`] with `p.weights`. Always
/// finite and positive; deterministic; bit-identical to
/// `features(..).dot(&p.weights).max(1e-12)` by construction.
pub fn predict(
    kernel: Kernel,
    dense_k: usize,
    exec: &ExecPlan,
    stats: &MatrixStats,
    p: &CostParams,
) -> f64 {
    features(kernel, dense_k, exec, stats, p).dot(&p.weights).max(1e-12)
}

/// Indices of `plans`' execution triples sorted by predicted time
/// (ascending, ties broken by index for determinism).
pub fn rank_execs(
    kernel: Kernel,
    dense_k: usize,
    execs: &[ExecPlan],
    stats: &MatrixStats,
    p: &CostParams,
) -> Vec<usize> {
    let scores: Vec<f64> =
        execs.iter().map(|e| predict(kernel, dense_k, e, stats, p)).collect();
    let mut idx: Vec<usize> = (0..execs.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// The k-aware batch-vs-loop verdict for `engine::batch`: predicted
/// seconds for serving `k` concurrent SpMV requests as `k` independent
/// SpMV calls versus one coalesced SpMM(k) panel.
#[derive(Clone, Copy, Debug)]
pub struct BatchDecision {
    /// `k ×` the cheapest predicted SpMV among `spmv_execs`.
    pub solo_secs: f64,
    /// Cheapest predicted SpMM at `dense_k = k` among `spmm_execs`,
    /// plus the pack/scatter panel traffic the loop path never pays.
    pub panel_secs: f64,
    /// Index into `spmm_execs` of the plan behind `panel_secs`.
    pub panel_exec: usize,
}

impl BatchDecision {
    /// Does coalescing the batch beat the per-request loop?
    pub fn batch_pays(&self) -> bool {
        self.panel_secs < self.solo_secs
    }
}

/// Predict `k × spmv` vs `spmm(k)` over caller-filtered candidate
/// plans (the batching queue restricts both sides to its bit-identity
/// canonical set before asking). The panel side is charged for packing
/// the k right-hand sides into a row-major panel and scattering the
/// result columns back out — `2 × 8` bytes per element each way at
/// stream bandwidth — which is exactly the overhead that makes small-k
/// batching lose and must therefore live inside the prediction, not in
/// a heuristic around it. Returns `None` when either side has no
/// candidates.
pub fn batch_decision(
    k: usize,
    spmv_execs: &[ExecPlan],
    spmm_execs: &[ExecPlan],
    stats: &MatrixStats,
    p: &CostParams,
) -> Option<BatchDecision> {
    let solo_one = spmv_execs
        .iter()
        .map(|e| predict(Kernel::Spmv, 1, e, stats, p))
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))?;
    let pack_scatter =
        16.0 * k as f64 * (stats.ncols + stats.nrows) as f64 * p.weights[F_STREAM];
    let (panel_exec, panel_kernel) = spmm_execs
        .iter()
        .map(|e| predict(Kernel::Spmm, k, e, stats, p))
        .enumerate()
        .min_by(|(ai, a), (bi, b)| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal).then(ai.cmp(bi))
        })?;
    Some(BatchDecision {
        solo_secs: k as f64 * solo_one,
        panel_secs: panel_kernel + pack_scatter,
        panel_exec,
    })
}

/// Re-plan margin at generation 0: a fresh plan must be predicted this
/// fraction faster than the incumbent before `apply_delta` re-runs the
/// full compile pipeline. The margin *decays* as deltas accumulate
/// (`/ (1 + deltas_applied / 8)`): a matrix that has drifted through
/// many generations is increasingly likely to have left the stats
/// neighborhood its plan was chosen in, so the threshold for paying the
/// re-plan loosens deterministically.
pub const REPLAN_BASE_MARGIN: f64 = 0.25;

/// Serves a re-plan's prepare cost is amortized over: re-planning must
/// win back the rebuild within this many invocations of the kernel.
pub const REPLAN_AMORTIZE_SERVES: f64 = 64.0;

/// What `Engine::apply_delta` should do with the storage generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaAction {
    /// Splice the delta into the existing storage (`SparseOps::repair`).
    Repair,
    /// Rebuild the same plan's storage from the post-delta tuples.
    Rebuild,
    /// Re-run the full predict→measure compile on the new stats.
    Replan,
}

/// The repair-vs-rebuild-vs-re-plan verdict for one delta application,
/// with the predicted costs behind it (auditable in `BENCH_delta.json`).
#[derive(Clone, Copy, Debug)]
pub struct DeltaDecision {
    pub action: DeltaAction,
    /// Predicted seconds to splice the delta into the current storage.
    pub repair_secs: f64,
    /// Predicted seconds to rebuild the current plan's storage from the
    /// post-delta tuple reservoir.
    pub rebuild_secs: f64,
    /// Predicted per-serve seconds a re-plan would recover
    /// (`current − best` on the post-delta stats, floored at 0).
    pub replan_gain_secs: f64,
}

/// Decide how `Engine::apply_delta` transitions the storage generation.
///
/// `current_predicted_secs` / `best_predicted_secs` are the incumbent
/// plan's and the shortlist winner's predicted serve times **on the
/// post-delta stats** (the caller re-ranks with [`rank_execs`] — this
/// function stays a pure arithmetic policy). Re-planning wins when the
/// predicted gain clears the accumulation-decayed margin *and* pays for
/// the rebuild within [`REPLAN_AMORTIZE_SERVES`] serves; otherwise the
/// cheaper of repair (when the format supports this batch) and rebuild
/// is taken. Deterministic: same inputs, same verdict.
pub fn delta_decision(
    new_stats: &MatrixStats,
    delta_nnz: usize,
    repair_supported: bool,
    current_predicted_secs: f64,
    best_predicted_secs: f64,
    deltas_applied: u64,
    p: &CostParams,
) -> DeltaDecision {
    let n = new_stats.nrows.max(1) as f64;
    let nnz = new_stats.nnz as f64;
    let w = p.weights[F_STREAM];
    // Rebuild re-sorts the tuple reservoir and writes the storage out:
    // about one read + one write of the structure's byte volume.
    let rebuild_secs = 2.0 * (nnz * 16.0 + n * 8.0) * w;
    // Repair streams the existing structure once (the splice copy) plus
    // per-op merge work — cheap for small batches, worse than a rebuild
    // once the delta is a sizable fraction of the matrix.
    let repair_secs = (nnz * 12.0 + n * 4.0 + delta_nnz as f64 * 64.0) * w;
    let gain = (current_predicted_secs - best_predicted_secs).max(0.0);
    let margin = REPLAN_BASE_MARGIN / (1.0 + deltas_applied as f64 / 8.0);
    let action = if gain > margin * best_predicted_secs.max(1e-12)
        && gain * REPLAN_AMORTIZE_SERVES > rebuild_secs
    {
        DeltaAction::Replan
    } else if repair_supported && repair_secs < rebuild_secs {
        DeltaAction::Repair
    } else {
        DeltaAction::Rebuild
    };
    DeltaDecision { action, repair_secs, rebuild_secs, replan_gain_secs: gain }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concretize::Plan;
    use crate::storage::EllOrder;

    fn csr() -> Plan {
        Plan::serial(Layout::Csr, Traversal::RowWise)
    }

    fn ell_plans() -> Vec<Plan> {
        vec![
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded),
            Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
        ]
    }

    /// The ISSUE's planted ranking: on a high-variance row-length
    /// matrix the padded formats drown in padding, so CSR must rank
    /// above every ELL executable…
    #[test]
    fn csr_beats_ell_on_high_variance_rows() {
        let p = CostParams::host_small();
        let skewed = MatrixStats::synthetic(1000, 1000, 8.0, 1600.0, 400, 900);
        let t_csr = predict(Kernel::Spmv, 1, &csr(), &skewed, &p);
        for e in ell_plans() {
            let t_ell = predict(Kernel::Spmv, 1, &e, &skewed, &p);
            assert!(
                t_csr < t_ell,
                "CSR {t_csr:e} not ranked above {:?} {t_ell:e} on skewed rows",
                e.layout
            );
        }
    }

    /// …and on perfectly uniform rows the branch-free padded ELL
    /// executable ranks above CSR (no padding, no row_ptr traffic, no
    /// per-row branch).
    #[test]
    fn ell_beats_csr_on_uniform_rows() {
        let p = CostParams::host_small();
        let uniform = MatrixStats::synthetic(1000, 1000, 8.0, 0.0, 8, 500);
        let t_csr = predict(Kernel::Spmv, 1, &csr(), &uniform, &p);
        let padded = Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded);
        let t_ell = predict(Kernel::Spmv, 1, &padded, &uniform, &p);
        assert!(t_ell < t_csr, "padded ELL {t_ell:e} not below CSR {t_csr:e} on uniform rows");
    }

    /// The batch predictor must charge the panel for pack/scatter (so
    /// k=1 never batches) and still find the crossover where one
    /// SpMM(k) pass beats k structure re-streams.
    #[test]
    fn batch_decision_crosses_over_with_k() {
        let p = CostParams::host_small();
        // Banded, so the gathers stay cache-resident on both sides and
        // the verdict reduces to (k-1) structure re-streams vs the
        // panel pack/scatter — deterministic under the seed weights.
        let stats = MatrixStats::synthetic(200_000, 200_000, 30.0, 100.0, 80, 8);
        let spmv = [csr()];
        let spmm = [csr()];
        let d1 = batch_decision(1, &spmv, &spmm, &stats, &p).unwrap();
        assert!(
            !d1.batch_pays(),
            "k=1 must never batch: panel {:e} vs solo {:e}",
            d1.panel_secs,
            d1.solo_secs
        );
        let d8 = batch_decision(8, &spmv, &spmm, &stats, &p).unwrap();
        assert!(
            d8.batch_pays(),
            "k=8 panel {:e} should beat {:e} (8 structure re-streams)",
            d8.panel_secs,
            d8.solo_secs
        );
        assert_eq!(d8.panel_exec, 0);
        assert!(batch_decision(4, &[], &spmm, &stats, &p).is_none());
        assert!(batch_decision(4, &spmv, &[], &stats, &p).is_none());
    }

    #[test]
    fn dia_only_competitive_when_banded() {
        let p = CostParams::host_small();
        let dia = Plan::serial(Layout::Dia, Traversal::DiagMajor);
        let banded = MatrixStats::synthetic(2000, 2000, 7.0, 1.0, 9, 4);
        let scattered = MatrixStats::synthetic(2000, 2000, 7.0, 1.0, 9, 1500);
        let t_banded = predict(Kernel::Spmv, 1, &dia, &banded, &p);
        let t_scattered = predict(Kernel::Spmv, 1, &dia, &scattered, &p);
        assert!(t_banded * 20.0 < t_scattered, "{t_banded:e} vs {t_scattered:e}");
        assert!(t_banded < predict(Kernel::Spmv, 1, &csr(), &banded, &p) * 3.0);
    }

    #[test]
    fn parallel_pays_spawn_cost_on_tiny_matrices() {
        let p = CostParams::host_large(8);
        let tiny = MatrixStats::synthetic(100, 100, 5.0, 2.0, 8, 50);
        let big = MatrixStats::synthetic(400_000, 400_000, 40.0, 100.0, 80, 200_000);
        let par = csr().with_schedule(Schedule::Parallel { threads: 8 });
        assert!(
            predict(Kernel::Spmv, 1, &par, &tiny, &p) > predict(Kernel::Spmv, 1, &csr(), &tiny, &p),
            "parallel should lose on a tiny matrix"
        );
        assert!(
            predict(Kernel::Spmv, 1, &par, &big, &p) < predict(Kernel::Spmv, 1, &csr(), &big, &p),
            "parallel should win on a large matrix"
        );
    }

    #[test]
    fn tiling_helps_only_when_x_spills_cache() {
        let p = CostParams::host_small();
        let tiled = csr().with_schedule(Schedule::Tiled { x_block: 4096 });
        let small = MatrixStats::synthetic(3000, 3000, 10.0, 9.0, 20, 1500);
        assert!(
            predict(Kernel::Spmv, 1, &tiled, &small, &p)
                > predict(Kernel::Spmv, 1, &csr(), &small, &p),
            "tiling must cost extra when x already fits in L2"
        );
        // On a huge matrix an L2-sized band pays off; a tiny band would
        // drown in per-band split/partial traffic (977 bands × 4M rows).
        let huge = MatrixStats::synthetic(4_000_000, 4_000_000, 30.0, 400.0, 200, 2_000_000);
        let l2_band = csr().with_schedule(Schedule::Tiled { x_block: 500_000 });
        assert!(
            predict(Kernel::Spmv, 1, &l2_band, &huge, &p)
                < predict(Kernel::Spmv, 1, &csr(), &huge, &p),
            "tiling must pay off once the gather working set spills"
        );
    }

    #[test]
    fn level_trsv_wins_only_when_levels_are_wide() {
        let p = CostParams::host_large(8);
        let serial = Plan::serial(Layout::Csr, Traversal::RowWise);
        let par = serial.with_schedule(Schedule::Parallel { threads: 8 });
        // Wide levels: 200k rows in ~40 waves → near-full speedup.
        let wide = MatrixStats::synthetic(200_000, 200_000, 12.0, 16.0, 30, 100_000)
            .with_dep_levels(40);
        assert!(
            predict(Kernel::Trsv, 1, &par, &wide, &p) < predict(Kernel::Trsv, 1, &serial, &wide, &p),
            "level schedule should win on wide level sets"
        );
        // A serial chain (banded): one row per level, no exploitable
        // parallelism — the supernoded waves save the barriers, but the
        // spawn cost still makes the level schedule a loser.
        let chain = MatrixStats::synthetic(200_000, 200_000, 12.0, 16.0, 30, 3);
        assert!(
            predict(Kernel::Trsv, 1, &par, &chain, &p) > predict(Kernel::Trsv, 1, &serial, &chain, &p),
            "level schedule must lose on a serial dependence chain"
        );
    }

    #[test]
    fn supernoded_waves_cut_the_sync_term() {
        // Same dependence depth; one stats object with per-level waves,
        // one with the narrow levels merged — the merged one must be
        // predicted cheaper (fewer barriers), all else equal.
        let p = CostParams::host_large(8);
        let par = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Parallel { threads: 8 });
        let base = MatrixStats::synthetic(50_000, 50_000, 6.0, 2.0, 10, 30);
        let mut per_level = base.with_dep_levels(20_000);
        per_level.sync_waves = 20_000; // pre-supernode behavior
        let mut merged = base.with_dep_levels(20_000);
        merged.sync_waves = 700;
        let t_per_level = predict(Kernel::Trsv, 1, &par, &per_level, &p);
        let t_merged = predict(Kernel::Trsv, 1, &par, &merged, &p);
        assert!(
            t_merged < t_per_level,
            "supernoding must reduce the predicted sync cost: {t_merged:e} vs {t_per_level:e}"
        );
        // The saving is exactly the sync weight times the wave delta.
        let saved = t_per_level - t_merged;
        let expect = (20_000.0 - 700.0) * 8.0 * p.weights[F_SYNCS];
        assert!((saved - expect).abs() <= 1e-9 * expect, "{saved:e} vs {expect:e}");
    }

    #[test]
    fn spmm_panel_tiling_pays_off_when_b_spills() {
        let p = CostParams::host_small();
        let k = 100;
        let serial = Plan::serial(Layout::Csr, Traversal::RowWise);
        let tiled = serial.with_schedule(Schedule::Tiled { x_block: 4096 });
        // Scattered columns, B = 200k × 100 doubles ≫ L2: the panel
        // sweep shrinks the gathered working set ~3×.
        let huge = MatrixStats::synthetic(200_000, 200_000, 20.0, 100.0, 80, 150_000);
        assert!(
            predict(Kernel::Spmm, k, &tiled, &huge, &p)
                < predict(Kernel::Spmm, k, &serial, &huge, &p),
            "B-panel tiling must win once B spills the cache"
        );
        // Small matrix: B fits, the extra structure streams only cost.
        let small = MatrixStats::synthetic(2000, 2000, 10.0, 9.0, 20, 1000);
        assert!(
            predict(Kernel::Spmm, k, &tiled, &small, &p)
                > predict(Kernel::Spmm, k, &serial, &small, &p),
            "B-panel tiling must cost extra when B is already resident"
        );
    }

    #[test]
    fn predictions_finite_positive_and_deterministic() {
        let p = CostParams::host_large(4);
        let stats = MatrixStats::of(&crate::matrix::TriMat::new(6, 6));
        for e in ell_plans().into_iter().chain([csr()]) {
            let a = predict(Kernel::Spmm, 16, &e, &stats, &p);
            let b = predict(Kernel::Spmm, 16, &e, &stats, &p);
            assert!(a.is_finite() && a > 0.0);
            assert_eq!(a, b);
        }
    }

    /// The calibration contract: the prediction *is* the dot product of
    /// the extracted features with the weight vector — bit-identical,
    /// for every schedule shape, so a fit over archived `(FeatureVec,
    /// measured)` samples scores plans exactly like the planner does.
    #[test]
    fn predict_is_exactly_features_dot_weights() {
        let plans = [
            csr(),
            csr().with_schedule(Schedule::Parallel { threads: 4 }),
            csr().with_schedule(Schedule::Tiled { x_block: 4096 }),
            csr().with_schedule(Schedule::ParallelTiled { threads: 4, x_block: 4096 }),
            Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
            Plan::serial(Layout::Dia, Traversal::DiagMajor),
        ];
        let stats = [
            MatrixStats::nominal(),
            MatrixStats::synthetic(100, 100, 5.0, 2.0, 8, 50),
            MatrixStats::synthetic(400_000, 400_000, 40.0, 100.0, 80, 200_000),
        ];
        for p in [CostParams::host_small(), CostParams::host_large(8)] {
            for e in &plans {
                for s in &stats {
                    for k in [Kernel::Spmv, Kernel::Spmm] {
                        let direct = predict(k, 16, e, s, &p);
                        let via = features(k, 16, e, s, &p).dot(&p.weights).max(1e-12);
                        assert_eq!(direct, via, "{e:?} on {k:?}");
                    }
                }
            }
        }
        // TrSv (incl. the level-scheduled path with its sync feature).
        let tri = MatrixStats::synthetic(50_000, 50_000, 6.0, 2.0, 10, 25_000)
            .with_dep_levels(100);
        let par = csr().with_schedule(Schedule::Parallel { threads: 8 });
        let p = CostParams::host_large(8);
        let f = features(Kernel::Trsv, 1, &par, &tri, &p);
        assert_eq!(predict(Kernel::Trsv, 1, &par, &tri, &p), f.dot(&p.weights));
        assert!(f.0[F_SYNCS] > 0.0 && f.0[F_SPAWNS] > 0.0);
    }

    /// Seed vectors keep the hand-set machine numbers; the imbalance
    /// entry seeds at zero so the closed-formula predictions are
    /// reproduced; serial plans never carry schedule features.
    #[test]
    fn seed_weights_and_feature_shape() {
        let p = CostParams::host_small();
        assert_eq!(p.weights[F_STREAM], 1.0 / 8e9);
        assert_eq!(p.weights[F_GATHER], 1.0 / 1.5e9);
        assert_eq!(p.weights[F_FLOPS], 1.0 / 4e9);
        assert_eq!(p.weights[F_HEADERS], 1.5e-9);
        assert_eq!(p.weights[F_SPAWNS], 2.5e-5);
        assert_eq!(p.weights[F_SYNCS], 4e-7);
        assert_eq!(p.weights[F_IMBALANCE], 0.0);
        assert_eq!(p.weights[F_GATHER_LANES], 0.0);
        assert_eq!(p.weights[F_REMOTE], 0.0);
        assert_eq!(p.threads, 1);
        assert_eq!(p.vector_bytes, 32.0);
        assert_eq!(p.sockets, 1, "seed machines are single-node");
        assert_eq!(FEATURE_NAMES.len(), N_FEATURES);
        let f = features(Kernel::Spmv, 1, &csr(), &MatrixStats::nominal(), &p);
        assert_eq!(f.0[F_SPAWNS], 0.0);
        assert_eq!(f.0[F_SYNCS], 0.0);
        assert_eq!(f.0[F_IMBALANCE], 0.0);
        assert_eq!(f.0[F_GATHER_LANES], 0.0, "scalar plans carry no lane term");
        assert_eq!(f.0[F_REMOTE], 0.0, "serial plans carry no remote term");
        assert!(f.0[F_STREAM] > 0.0);
        // with_weights swaps the fitted half only.
        let w2 = [1e-10, 1e-9, 1e-10, 1e-9, 1e-5, 1e-7, 1e-12, 1e-9, 1e-11];
        let q = p.with_weights(w2);
        assert_eq!(q.weights, w2);
        assert_eq!(q.l2_bytes, p.l2_bytes);
        assert_eq!(q.threads, p.threads);
        assert_eq!(q.sockets, p.sockets);
    }

    /// The NUMA axis is priced the same way as the lane axis: a
    /// structural `sockets` knob exposes the cross-socket byte share in
    /// the appended `remote_bytes` entry with a zero seed weight, so
    /// single-socket machines and serial plans are bit-identical to the
    /// pre-NUMA extractor and only a calibration refit on a multi-node
    /// box prices the traffic.
    #[test]
    fn remote_bytes_prices_cross_socket_traffic() {
        let stats = MatrixStats::synthetic(400_000, 400_000, 40.0, 100.0, 80, 200_000);
        let par = csr().with_schedule(Schedule::Parallel { threads: 8 });
        let one = CostParams::host_large(8);
        let two = CostParams::host_large(8).with_sockets(2);
        // Single socket (and every serial plan): the entry stays zero.
        assert_eq!(features(Kernel::Spmv, 1, &par, &stats, &one).0[F_REMOTE], 0.0);
        assert_eq!(features(Kernel::Spmv, 1, &csr(), &stats, &two).0[F_REMOTE], 0.0);
        // Two sockets: half the parallel byte volume is charged remote.
        let f1 = features(Kernel::Spmv, 1, &par, &stats, &one);
        let f2 = features(Kernel::Spmv, 1, &par, &stats, &two);
        assert!(f2.0[F_REMOTE] > 0.0);
        // Half the parallel byte volume, up to f64 re-association.
        let expect = 0.5 * (f2.0[F_STREAM] + f2.0[F_GATHER]);
        assert!((f2.0[F_REMOTE] - expect).abs() <= 1e-12 * expect);
        // All other entries are untouched by the socket count…
        for i in 0..N_FEATURES {
            if i != F_REMOTE {
                assert_eq!(f1.0[i], f2.0[i], "feature {i} must not depend on sockets");
            }
        }
        // …so under the zero seed weight predictions are bit-identical,
        assert_eq!(
            predict(Kernel::Spmv, 1, &par, &stats, &one),
            predict(Kernel::Spmv, 1, &par, &stats, &two),
        );
        // and a fitted remote price can demote a parallel plan.
        let mut w = two.weights;
        w[F_REMOTE] = 1e-8;
        let fitted = two.with_weights(w);
        assert!(
            predict(Kernel::Spmv, 1, &par, &stats, &fitted)
                > predict(Kernel::Spmv, 1, &par, &stats, &two),
            "a fitted remote-byte penalty must be able to demote parallel plans"
        );
        // The level-scheduled TrSv arm carries the term too.
        let tri = MatrixStats::synthetic(50_000, 50_000, 6.0, 2.0, 10, 25_000)
            .with_dep_levels(100);
        let ft = features(Kernel::Trsv, 1, &par, &tri, &two);
        let expect = 0.5 * (ft.0[F_STREAM] + ft.0[F_GATHER]);
        assert!((ft.0[F_REMOTE] - expect).abs() <= 1e-12 * expect);
        assert!(with_sockets_is_clamped());
    }

    fn with_sockets_is_clamped() -> bool {
        CostParams::host_small().with_sockets(0).sockets == 1
    }

    /// The lane axis is priced: a wide plan keeps its byte features,
    /// shrinks its flop/header units by the register-capped lane count,
    /// and carries the hardware-gather count in the appended
    /// `gather_lanes` entry (zero seed weight — a refit prices it).
    #[test]
    fn lane_axis_prices_vector_width() {
        let p = CostParams::host_small();
        let stats = MatrixStats::synthetic(3000, 3000, 10.0, 9.0, 20, 1500);
        let scalar = csr();
        let wide = csr().with_lanes(4);
        let fs = features(Kernel::Spmv, 1, &scalar, &stats, &p);
        let fw = features(Kernel::Spmv, 1, &wide, &stats, &p);
        // Byte traffic is lane-independent.
        assert_eq!(fs.0[F_STREAM], fw.0[F_STREAM]);
        assert_eq!(fs.0[F_GATHER], fw.0[F_GATHER]);
        // Headers shrink by the lane count; the lane entry appears.
        assert_eq!(fw.0[F_HEADERS], fs.0[F_HEADERS] / 4.0);
        assert!(fw.0[F_GATHER_LANES] > 0.0);
        assert_eq!(fs.0[F_GATHER_LANES], 0.0);
        // Under seed weights (lane weight 0) the wide plan never ranks
        // worse than scalar; a fitted gather-lane penalty can flip it.
        let t_scalar = predict(Kernel::Spmv, 1, &scalar, &stats, &p);
        let t_wide = predict(Kernel::Spmv, 1, &wide, &stats, &p);
        assert!(t_wide <= t_scalar);
        let mut w = p.weights;
        w[F_GATHER_LANES] = 1e-6;
        let fitted = p.with_weights(w);
        assert!(
            predict(Kernel::Spmv, 1, &wide, &stats, &fitted)
                > predict(Kernel::Spmv, 1, &scalar, &stats, &fitted),
            "a fitted gather-lane penalty must be able to demote wide plans"
        );
        // An 8-lane plan on a 4-lane (32-byte) machine double-pumps:
        // flop/header units divide by the register cap, not the plan.
        let v8 = csr().with_lanes(8);
        let f8 = features(Kernel::Spmv, 1, &v8, &stats, &p);
        assert_eq!(f8.0[F_HEADERS], fs.0[F_HEADERS] / 4.0);
        // …but the gather count still amortizes over all 8 lanes.
        assert!(f8.0[F_GATHER_LANES] < fw.0[F_GATHER_LANES]);
    }

    /// Small batches splice, missing capability rebuilds, and a delta
    /// comparable to the matrix makes the splice pass costlier than a
    /// from-tuples rebuild.
    #[test]
    fn delta_decision_picks_repair_rebuild_by_cost() {
        let p = CostParams::host_small();
        let stats = MatrixStats::synthetic(100_000, 100_000, 10.0, 4.0, 20, 50_000);
        let t = 1e-3;
        let small = delta_decision(&stats, 64, true, t, t, 0, &p);
        assert_eq!(small.action, DeltaAction::Repair);
        assert!(small.repair_secs < small.rebuild_secs);
        assert_eq!(small.replan_gain_secs, 0.0);
        let unsupported = delta_decision(&stats, 64, false, t, t, 0, &p);
        assert_eq!(unsupported.action, DeltaAction::Rebuild);
        let huge = delta_decision(&stats, 2_000_000, true, t, t, 0, &p);
        assert_eq!(huge.action, DeltaAction::Rebuild);
        assert!(huge.repair_secs > huge.rebuild_secs);
    }

    /// A big predicted gain on the post-delta stats re-plans; the same
    /// drift with no gain never does.
    #[test]
    fn delta_decision_replans_on_predicted_gain() {
        let p = CostParams::host_small();
        let stats = MatrixStats::synthetic(100_000, 100_000, 10.0, 4.0, 20, 50_000);
        let d = delta_decision(&stats, 64, true, 1e-3, 2e-4, 0, &p);
        assert_eq!(d.action, DeltaAction::Replan);
        assert!((d.replan_gain_secs - 8e-4).abs() < 1e-12);
        // Incumbent already best: stays on the cheap structural path.
        let no_gain = delta_decision(&stats, 64, true, 2e-4, 2e-4, 0, &p);
        assert_eq!(no_gain.action, DeltaAction::Repair);
    }

    /// The accumulation decay: a gain below the generation-0 margin
    /// clears it after enough deltas have piled onto the generation.
    #[test]
    fn delta_decision_margin_decays_with_accumulated_deltas() {
        let p = CostParams::host_small();
        let stats = MatrixStats::synthetic(100_000, 100_000, 10.0, 4.0, 20, 50_000);
        let (current, best) = (1.1e-3, 1.0e-3); // 10% gain < 25% margin
        let fresh = delta_decision(&stats, 64, true, current, best, 0, &p);
        assert_eq!(fresh.action, DeltaAction::Repair);
        let drifted = delta_decision(&stats, 64, true, current, best, 100, &p);
        assert_eq!(drifted.action, DeltaAction::Replan);
    }

    #[test]
    fn rank_execs_is_sorted_and_complete() {
        let p = CostParams::host_small();
        let stats = MatrixStats::nominal();
        let execs: Vec<Plan> = ell_plans().into_iter().chain([csr()]).collect();
        let order = rank_execs(Kernel::Spmv, 1, &execs, &stats, &p);
        assert_eq!(order.len(), execs.len());
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..execs.len()).collect::<Vec<_>>());
        for w in order.windows(2) {
            let a = predict(Kernel::Spmv, 1, &execs[w[0]], &stats, &p);
            let b = predict(Kernel::Spmv, 1, &execs[w[1]], &stats, &p);
            assert!(a <= b);
        }
    }
}
