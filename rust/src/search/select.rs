//! Per-architecture all-round kernel selection (paper §6.4.5).
//!
//! Method: pick a small random selection of `k` matrices; determine the
//! per-matrix optimal variant; keep the variants within `t%` of the
//! optimum on *all* k selected matrices; each such candidate is an
//! "all-round kernel". Its quality over the full collection is the
//! average reduction of execution time vs the per-matrix optimum — the
//! paper reports the *worst* such average (Table 5b) against the *best*
//! library routine's average (Table 5a).

use crate::search::coverage::{self, Measurements};
use crate::search::plan::Plan;
use crate::util::rng::Rng;
use crate::util::stats::pct_reduction;

/// Per-matrix winner of the predict→measure pipeline: the best
/// (layout, traversal, schedule) triple on one matrix.
#[derive(Clone, Debug)]
pub struct BestTriple {
    pub matrix: String,
    /// Row index into the measurements / `plans` slice.
    pub plan_index: usize,
    /// Stable plan id (`csr.row.par4`, …).
    pub plan_id: String,
    pub secs: f64,
}

/// The per-matrix best triples of a measured table whose first
/// `plans.len()` rows are the generated plans (extra rows — e.g. the
/// XLA backend — are ignored). Ties break to the earliest plan.
pub fn best_triples(meas: &Measurements, plans: &[Plan]) -> Vec<BestTriple> {
    let rows: Vec<usize> = (0..plans.len().min(meas.routines.len())).collect();
    let winners = meas.argmin_per_matrix(Some(&rows));
    winners
        .into_iter()
        .enumerate()
        .map(|(mi, r)| BestTriple {
            matrix: meas.matrices[mi].clone(),
            plan_index: r,
            plan_id: plans[r].id.clone(),
            secs: meas.times[r][mi],
        })
        .collect()
}

/// Coverage curves with and without the schedule axis: `(serial_only,
/// all_schedules)` sampled at `t_values`, both against the all-plan
/// optimum — quantifying what the third plan axis buys (the ROADMAP's
/// schedule-aware-selection item).
pub fn schedule_axis_curves(
    meas: &Measurements,
    plans: &[Plan],
    t_values: &[f64],
) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
    let all: Vec<usize> = (0..plans.len().min(meas.routines.len())).collect();
    let serial: Vec<usize> =
        all.iter().copied().filter(|&r| plans[r].exec.schedule.is_serial()).collect();
    let best = meas.best_per_matrix(Some(&all));
    let serial_curve = coverage::coverage_curve(meas, &best, Some(&serial), t_values);
    let all_curve = coverage::coverage_curve(meas, &best, Some(&all), t_values);
    (serial_curve, all_curve)
}

/// Average % reduction of the per-matrix optimum vs routine `r`
/// (how far `r` is from optimal on average; smaller is better).
pub fn avg_reduction_vs_optimum(meas: &Measurements, best: &[f64], r: usize) -> f64 {
    let n = meas.matrices.len();
    let total: f64 = (0..n).map(|m| pct_reduction(best[m], meas.times[r][m])).sum();
    total / n as f64
}

/// Table 5(a): the minimum average reduction over a set of (library)
/// routines — i.e. the best library routine's distance from optimal.
pub fn min_avg_reduction(meas: &Measurements, best: &[f64], subset: &[usize]) -> f64 {
    subset
        .iter()
        .map(|&r| avg_reduction_vs_optimum(meas, best, r))
        .fold(f64::INFINITY, f64::min)
}

/// Outcome of the selection method.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Indices of the sampled matrices.
    pub sample: Vec<usize>,
    /// Candidate routines within t% of optimum on every sampled matrix.
    pub candidates: Vec<usize>,
    /// Worst average reduction among candidates (Table 5b).
    pub worst_avg_reduction: f64,
    /// Best average reduction among candidates.
    pub best_avg_reduction: f64,
}

/// Run the §6.4.5 method: `k` random matrices, tolerance `t_pct`,
/// candidates drawn from `subset` (the generated variants), optimum over
/// the full `meas` collection.
pub fn select_allround(
    meas: &Measurements,
    best: &[f64],
    subset: &[usize],
    k: usize,
    t_pct: f64,
    rng: &mut Rng,
) -> Selection {
    let n = meas.matrices.len();
    let k = k.min(n);
    let sample = rng.sample_distinct(n, k);

    let mut candidates: Vec<usize> = subset
        .iter()
        .copied()
        .filter(|&r| {
            sample.iter().all(|&m| meas.times[r][m] <= (1.0 + t_pct / 100.0) * best[m])
        })
        .collect();

    // If the tolerance is too tight for any single routine, relax to the
    // routine(s) closest to optimal on the sample (the paper's method
    // assumes a candidate exists; we make the fallback explicit).
    if candidates.is_empty() {
        let score = |r: usize| -> f64 {
            sample.iter().map(|&m| meas.times[r][m] / best[m]).fold(0.0, f64::max)
        };
        let best_r = subset
            .iter()
            .copied()
            .min_by(|&a, &b| score(a).partial_cmp(&score(b)).unwrap())
            .expect("non-empty subset");
        candidates.push(best_r);
    }

    let reductions: Vec<f64> = candidates
        .iter()
        .map(|&r| avg_reduction_vs_optimum(meas, best, r))
        .collect();
    let worst = reductions.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let besta = reductions.iter().cloned().fold(f64::INFINITY, f64::min);
    Selection { sample, candidates, worst_avg_reduction: worst, best_avg_reduction: besta }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Measurements {
        // 4 matrices; r0 optimal everywhere; r1 always 10% off;
        // r2 optimal on m0 but 3x elsewhere.
        let mut m = Measurements::new(
            vec!["r0".into(), "r1".into(), "r2".into()],
            (0..4).map(|i| format!("m{i}")).collect(),
        );
        let data = [[1.0, 1.0, 1.0, 1.0], [1.1, 1.1, 1.1, 1.1], [1.0, 3.0, 3.0, 3.0]];
        for (r, row) in data.iter().enumerate() {
            for (c, &t) in row.iter().enumerate() {
                m.set(r, c, t);
            }
        }
        m
    }

    #[test]
    fn avg_reduction_sane() {
        let m = table();
        let best = m.best_per_matrix(None);
        assert!((avg_reduction_vs_optimum(&m, &best, 0) - 0.0).abs() < 1e-12);
        let r1 = avg_reduction_vs_optimum(&m, &best, 1);
        assert!((r1 - 100.0 * (1.0 - 1.0 / 1.1)).abs() < 1e-9);
    }

    #[test]
    fn min_avg_picks_best_library() {
        let m = table();
        let best = m.best_per_matrix(None);
        let v = min_avg_reduction(&m, &best, &[1, 2]);
        // r1 ≈ 9.09%, r2 = (0 + 3×66.7)/4 = 50%.
        assert!((v - 100.0 * (1.0 - 1.0 / 1.1)).abs() < 1e-9);
    }

    #[test]
    fn selection_finds_allround_r0() {
        let m = table();
        let best = m.best_per_matrix(None);
        let mut rng = Rng::new(3);
        let sel = select_allround(&m, &best, &[0, 1, 2], 2, 2.0, &mut rng);
        assert!(sel.candidates.contains(&0));
        assert!(sel.worst_avg_reduction <= 10.0);
    }

    #[test]
    fn fallback_when_tolerance_too_tight() {
        let mut m = table();
        // make every routine ≥5% off optimal somewhere by adding a
        // synthetic optimal routine not in the subset
        let mut extra = Measurements::new(vec!["opt".into()], m.matrices.clone());
        for c in 0..4 {
            extra.set(0, c, 0.5);
        }
        m.extend(&extra);
        let best = m.best_per_matrix(None);
        let mut rng = Rng::new(4);
        let sel = select_allround(&m, &best, &[0, 1, 2], 3, 2.0, &mut rng);
        assert_eq!(sel.candidates.len(), 1);
    }

    #[test]
    fn selection_deterministic_per_seed() {
        let m = table();
        let best = m.best_per_matrix(None);
        let a = select_allround(&m, &best, &[0, 1, 2], 2, 2.0, &mut Rng::new(7));
        let b = select_allround(&m, &best, &[0, 1, 2], 2, 2.0, &mut Rng::new(7));
        assert_eq!(a.sample, b.sample);
        assert_eq!(a.candidates, b.candidates);
    }

    use crate::baselines::Kernel;
    use crate::concretize::{Layout, Plan as ExecPlan, Schedule, Traversal};
    use crate::forelem::ir::ChainState;

    /// Three plans (serial CSR, parallel CSR, serial padded ELL) over
    /// a table with a planted per-matrix winner.
    fn planted() -> (Measurements, Vec<Plan>) {
        let state = ChainState::initial(Kernel::Spmv);
        let mk = |e: ExecPlan| Plan::new(state.clone(), String::new(), e);
        let csr = ExecPlan::serial(Layout::Csr, Traversal::RowWise);
        let plans = vec![
            mk(csr),
            mk(csr.with_schedule(Schedule::Parallel { threads: 4 })),
            mk(ExecPlan::serial(Layout::Ell(crate::storage::EllOrder::RowMajor), Traversal::RowWisePadded)),
        ];
        let mut m = Measurements::new(
            plans.iter().map(|p| p.id.clone()).collect(),
            vec!["small".into(), "big".into(), "uniform".into()],
        );
        // Planted winners: serial CSR on "small", parallel CSR on
        // "big", padded ELL on "uniform".
        let data = [[1.0, 8.0, 3.0], [5.0, 2.0, 4.0], [2.0, 9.0, 1.0]];
        for (r, row) in data.iter().enumerate() {
            for (c, &t) in row.iter().enumerate() {
                m.set(r, c, t);
            }
        }
        (m, plans)
    }

    #[test]
    fn best_triples_find_planted_winners() {
        let (m, plans) = planted();
        let best = best_triples(&m, &plans);
        assert_eq!(best.len(), 3);
        assert_eq!(best[0].plan_id, "csr.row.serial");
        assert_eq!(best[1].plan_id, "csr.row.par4");
        assert_eq!(best[2].plan_id, "ell-rm.rowpad.serial");
        assert_eq!(best[1].plan_index, 1);
        assert!((best[1].secs - 2.0).abs() < 1e-12);
        assert_eq!(best[0].matrix, "small");
    }

    #[test]
    fn best_triples_ignore_extra_rows() {
        // An extra (XLA) row beyond the plan rows must never win.
        let (mut m, plans) = planted();
        let mut extra = Measurements::new(vec!["xla".into()], m.matrices.clone());
        for c in 0..3 {
            extra.set(0, c, 0.01);
        }
        m.extend(&extra);
        let best = best_triples(&m, &plans);
        assert!(best.iter().all(|b| b.plan_index < plans.len()));
        assert_eq!(best[0].plan_id, "csr.row.serial");
    }

    #[test]
    fn schedule_axis_curves_show_the_axis_payoff() {
        let (m, plans) = planted();
        let ts = [0.0, 50.0, 200.0, 400.0];
        let (serial_curve, all_curve) = schedule_axis_curves(&m, &plans, &ts);
        assert_eq!(serial_curve.len(), ts.len());
        assert_eq!(all_curve.len(), ts.len());
        // The full space always covers at least as much as serial-only.
        for (s, a) in serial_curve.iter().zip(&all_curve) {
            assert!(a.1 >= s.1 - 1e-12, "axis lost coverage at t={}", s.0);
        }
        // At t = 0 every plan is optimal on exactly one matrix, so the
        // max single-plan weight is 1/3 for both subsets.
        assert!((serial_curve[0].1 - 1.0 / 3.0).abs() < 1e-12);
        assert!((all_curve[0].1 - 1.0 / 3.0).abs() < 1e-12);
        // At t = 200% serial CSR covers "small" and "uniform" but still
        // misses "big" (8.0 vs best 2.0 needs t = 300%).
        assert!((serial_curve[2].1 - 2.0 / 3.0).abs() < 1e-12);
        // At t = 400% serial CSR covers everything.
        let (serial_hi, all_hi) = (serial_curve[3].1, all_curve[3].1);
        assert!((serial_hi - 1.0).abs() < 1e-12);
        assert!((all_hi - 1.0).abs() < 1e-12);
    }

    #[test]
    fn best_triples_subset_edge_cases() {
        let (m, plans) = planted();
        // No plans → no triples.
        assert!(best_triples(&m, &[]).is_empty());
        // One plan → it wins every matrix.
        let one = &plans[..1];
        let best = best_triples(&m, one);
        assert!(best.iter().all(|b| b.plan_index == 0));
    }
}
