//! Self-calibration of the analytic cost model — the refit half of the
//! predict→measure→**refit** loop.
//!
//! # The feature order contract
//!
//! Since the fittable refactor, `search::cost` predicts a plan's time
//! as the dot product of a fixed-order [`FeatureVec`]
//! (`cost::FEATURE_NAMES`: stream bytes, gather bytes, flops, loop
//! headers, spawns, barrier waves, imbalance bytes, gather-lane ops)
//! with
//! `CostParams::weights`. Every array persisted by this module — the
//! per-cell samples in `BENCH_*.json`, the `weight` lines of a
//! `.profile` file — uses **exactly that order**; index `i` always
//! means `FEATURE_NAMES[i]`. New features are appended, never
//! reordered, so old sample archives stay refittable.
//!
//! The extractor resolves its nonlinearity (L2 miss split, roofline,
//! effective parallel speedup) against the parameters active when the
//! sample was *measured* — a fit is therefore a linearization around
//! the recording parameters (the seed vector on a fresh machine),
//! which is exactly the regime the fitted profile is applied in.
//!
//! # The fit
//!
//! [`fit`] solves a non-negative least-squares problem (hand-rolled
//! coordinate descent on the normal equations — no dependencies) over
//! `(FeatureVec, measured_seconds)` samples, minimizing *relative*
//! residual (each row is scaled by `1/measured`) so microsecond
//! matrices count as much as millisecond ones — the planner cares
//! about ranking, not absolute seconds. Columns are scaled to unit
//! max for conditioning and unscaled on the way out. A feature that
//! never occurs in the sample set (e.g. `syncs` in an SpMV-only
//! archive) keeps its seed weight instead of collapsing to zero.
//!
//! # The loop
//!
//! `coordinator::sweep` records a sample for every measured cell;
//! `bench-json` archives them (plus a preview refit) in
//! `BENCH_spmv.json`; `forelem calibrate` fits one or more such
//! archives into a [`Profile`] persisted at
//! `target/tuning/<arch>.profile` (`runtime::artifacts`), which the
//! CLI sweeps auto-load on the next run. CI re-scores top-1 agreement
//! under the fitted profile and fails if it drops below the seed's.

use crate::search::cost::{CostParams, FEATURE_NAMES, N_FEATURES};

/// One measured cell of a sweep: the plan's feature vector on that
/// matrix (extracted under the recording parameters), the measured
/// median seconds, and the prediction that ranked it.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub matrix: String,
    pub plan_id: String,
    pub features: [f64; N_FEATURES],
    pub measured_secs: f64,
    pub predicted_secs: f64,
}

/// Non-negative least squares via cyclic coordinate descent on the
/// normal equations: minimize `‖Xw − y‖²` subject to `w ≥ 0`. The
/// objective is convex quadratic, so exact per-coordinate minimization
/// with clamping converges. Columns whose diagonal Gram entry is zero
/// (feature absent from every row) keep their warm-start value `w0`.
pub fn nnls(xs: &[[f64; N_FEATURES]], y: &[f64], w0: &[f64; N_FEATURES]) -> [f64; N_FEATURES] {
    assert_eq!(xs.len(), y.len());
    let mut gram = [[0.0f64; N_FEATURES]; N_FEATURES];
    let mut rhs = [0.0f64; N_FEATURES];
    for (row, &yi) in xs.iter().zip(y) {
        for (j, &xj) in row.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            rhs[j] += xj * yi;
            for (k, &xk) in row.iter().enumerate() {
                gram[j][k] += xj * xk;
            }
        }
    }
    let mut w = *w0;
    for (j, wj) in w.iter_mut().enumerate() {
        if gram[j][j] <= 0.0 {
            *wj = w0[j];
        } else {
            *wj = wj.max(0.0);
        }
    }
    for _ in 0..2000 {
        let mut delta = 0.0f64;
        for j in 0..N_FEATURES {
            if gram[j][j] <= 0.0 {
                continue;
            }
            let mut r = rhs[j];
            for k in 0..N_FEATURES {
                if k != j {
                    r -= gram[j][k] * w[k];
                }
            }
            let next = (r / gram[j][j]).max(0.0);
            delta = delta.max((next - w[j]).abs());
            w[j] = next;
        }
        if delta < 1e-14 {
            break;
        }
    }
    w
}

/// Fit a weight vector from measured samples, starting from (and
/// falling back to) `seed`. Returns `seed` untouched when there is
/// nothing to fit. The structural machine shape (`l2_bytes`,
/// `threads`) is carried over from the seed.
pub fn fit(samples: &[Sample], seed: &CostParams) -> CostParams {
    if samples.is_empty() {
        return *seed;
    }
    // Relative weighting: scale each equation by 1/measured so the fit
    // optimizes ranking-relevant relative error.
    let mut xs: Vec<[f64; N_FEATURES]> = Vec::with_capacity(samples.len());
    let mut y: Vec<f64> = Vec::with_capacity(samples.len());
    for s in samples {
        let m = s.measured_secs.max(1e-12);
        let mut row = [0.0; N_FEATURES];
        for (r, &f) in row.iter_mut().zip(&s.features) {
            *r = f / m;
        }
        xs.push(row);
        y.push(1.0);
    }
    // Column scaling to unit max for conditioning.
    let mut scale = [1.0f64; N_FEATURES];
    for (j, sj) in scale.iter_mut().enumerate() {
        let mx = xs.iter().map(|r| r[j].abs()).fold(0.0f64, f64::max);
        if mx > 0.0 {
            *sj = mx;
        }
    }
    for row in &mut xs {
        for (v, sj) in row.iter_mut().zip(&scale) {
            *v /= sj;
        }
    }
    let mut w0 = [0.0; N_FEATURES];
    for ((w, sj), &sw) in w0.iter_mut().zip(&scale).zip(&seed.weights) {
        *w = sw * sj;
    }
    let w_scaled = nnls(&xs, &y, &w0);
    let mut weights = [0.0; N_FEATURES];
    for ((w, ws), sj) in weights.iter_mut().zip(&w_scaled).zip(&scale) {
        *w = ws / sj;
    }
    seed.with_weights(weights)
}

/// The shared core of the agreement metrics: group samples by matrix
/// (insertion order), take each group's predicted-side and
/// measured-side winners (ties to the earliest sample, mirroring the
/// sweep's ordering), and count groups where both winners are the same
/// *plan*. Comparing by plan id keeps merged archives with duplicate
/// `(matrix, plan)` samples (several `BENCH_*.json` files) from
/// deflating agreement when the two rankings pick different copies of
/// the same plan. One implementation so every caller — CLI gate,
/// bench-json preview, tests — groups and tie-breaks identically.
fn agreement_by(samples: &[Sample], predicted: &dyn Fn(&Sample) -> f64) -> (usize, usize) {
    let mut groups: Vec<(&str, Vec<&Sample>)> = Vec::new();
    for s in samples {
        match groups.iter_mut().find(|(m, _)| *m == s.matrix) {
            Some((_, v)) => v.push(s),
            None => groups.push((&s.matrix, vec![s])),
        }
    }
    let matches = groups
        .iter()
        .filter(|(_, g)| {
            argmin_by(g, predicted).plan_id
                == argmin_by(g, &|s: &Sample| s.measured_secs).plan_id
        })
        .count();
    (matches, groups.len())
}

/// First sample minimizing `key` (ties to the earliest — the sweep's
/// ordering). A free function so the returned borrow can carry the
/// explicit slice lifetime (closure signatures can't link an elided
/// output lifetime to an input).
fn argmin_by<'a>(g: &[&'a Sample], key: &dyn Fn(&Sample) -> f64) -> &'a Sample {
    let mut best = 0;
    for (i, s) in g.iter().enumerate() {
        if key(s) < key(g[best]) {
            best = i;
        }
    }
    g[best]
}

/// Predicted-vs-measured top-1 agreement of a sample set under a weight
/// vector: for each matrix, is the *plan* the weights rank first also
/// the plan with the smallest measured time? Returns
/// `(matches, matrices)`.
pub fn top1_agreement(samples: &[Sample], weights: &[f64; N_FEATURES]) -> (usize, usize) {
    agreement_by(samples, &|s: &Sample| {
        s.features.iter().zip(weights).map(|(f, w)| f * w).sum()
    })
}

/// Top-1 agreement of the *recording* planner: like
/// [`top1_agreement`], but ranking by the `predicted_secs` each sample
/// was archived with — i.e. the prediction of whatever weights (seed
/// or an already-fitted profile) actually ranked that sweep. This is
/// the honest baseline for a refit gate: dotting archived features
/// with seed weights would mis-score records produced under a loaded
/// profile, since the extractor resolved its nonlinearity against the
/// recording weights.
pub fn top1_agreement_recorded(samples: &[Sample]) -> (usize, usize) {
    agreement_by(samples, &|s: &Sample| s.predicted_secs)
}

/// A fitted per-machine parameter profile — what `forelem calibrate`
/// persists and the sweeps auto-load (`runtime::artifacts`).
#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    /// Architecture slug (`host-small` / `host-large`) — the file stem.
    pub arch_slug: String,
    pub l2_bytes: f64,
    pub threads: usize,
    /// Fitted weights, `FEATURE_NAMES` order.
    pub weights: [f64; N_FEATURES],
    /// Number of samples the fit consumed.
    pub samples: usize,
}

impl Profile {
    /// Build from fitted parameters.
    pub fn from_params(arch_slug: &str, p: &CostParams, samples: usize) -> Self {
        Profile {
            arch_slug: arch_slug.to_string(),
            l2_bytes: p.l2_bytes,
            threads: p.threads,
            weights: p.weights,
            samples,
        }
    }

    /// The profile as planner parameters, with the thread count pinned
    /// to the machine actually running (profiles may travel).
    pub fn params_for(&self, threads: usize) -> CostParams {
        CostParams {
            l2_bytes: self.l2_bytes,
            threads: threads.max(1),
            // Profiles predate the vector-width axis and don't persist
            // it; the structural register width is a property of the
            // ISA generation, not of the fit — AVX2's 32 bytes.
            vector_bytes: 32.0,
            // Likewise structural: the socket count belongs to the
            // machine serving the profile, not to the fit — the caller
            // (engine/sweep) applies `runtime::topology::sockets()`.
            sockets: crate::runtime::topology::sockets(),
            weights: self.weights,
        }
    }

    /// Plain-text serialization (`key value` lines; floats use Rust's
    /// round-trip formatting, so parse(render(p)) == p exactly).
    pub fn render(&self) -> String {
        let mut out = String::from("# forelem tuning profile (search::calibrate)\n");
        out.push_str(&format!("arch {}\n", self.arch_slug));
        out.push_str(&format!("l2_bytes {:e}\n", self.l2_bytes));
        out.push_str(&format!("threads {}\n", self.threads));
        out.push_str(&format!("samples {}\n", self.samples));
        for (name, w) in FEATURE_NAMES.iter().zip(&self.weights) {
            out.push_str(&format!("weight {name} {w:e}\n"));
        }
        out
    }

    /// Parse [`render`](Self::render)'s format. Unknown keys are
    /// ignored (forward compatibility); missing fields are errors, as
    /// is a weight named outside the feature contract.
    pub fn parse(text: &str) -> Result<Profile, String> {
        let mut arch = None;
        let mut l2_bytes = None;
        let mut threads = None;
        let mut samples = 0usize;
        let mut weights = [f64::NAN; N_FEATURES];
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().unwrap_or("");
            match key {
                "arch" => arch = it.next().map(str::to_string),
                "l2_bytes" => {
                    l2_bytes =
                        Some(parse_f64(it.next().ok_or("l2_bytes missing value")?)?)
                }
                "threads" => {
                    threads = Some(
                        it.next()
                            .ok_or("threads missing value")?
                            .parse::<usize>()
                            .map_err(|e| format!("bad threads: {e}"))?,
                    )
                }
                "samples" => {
                    samples = it
                        .next()
                        .ok_or("samples missing value")?
                        .parse()
                        .map_err(|e| format!("bad samples: {e}"))?
                }
                "weight" => {
                    let name = it.next().ok_or("weight missing name")?;
                    let val = parse_f64(it.next().ok_or("weight missing value")?)?;
                    let idx = FEATURE_NAMES
                        .iter()
                        .position(|n| *n == name)
                        .ok_or_else(|| format!("unknown feature '{name}'"))?;
                    weights[idx] = val;
                }
                _ => {} // forward compatibility
            }
        }
        let arch_slug = arch.ok_or("missing arch")?;
        let l2_bytes = l2_bytes.ok_or("missing l2_bytes")?;
        let threads = threads.ok_or("missing threads")?;
        if weights.iter().all(|w| w.is_nan()) {
            return Err("profile missing weight lines".into());
        }
        // Append-only contract: a profile fitted before a feature was
        // appended simply never saw it — its contribution was 0 then,
        // so 0 is its faithful weight now.
        for w in &mut weights {
            if w.is_nan() {
                *w = 0.0;
            }
        }
        Ok(Profile { arch_slug, l2_bytes, threads, weights, samples })
    }
}

fn parse_f64(s: &str) -> Result<f64, String> {
    s.parse::<f64>().map_err(|e| format!("bad float '{s}': {e}"))
}

// ------------------------------------------------- BENCH_*.json I/O --

fn str_field(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\": \"");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => out.push(chars.next()?),
            c => out.push(c),
        }
    }
    None
}

fn num_field(line: &str, key: &str) -> Option<f64> {
    let tag = format!("\"{key}\": ");
    let start = line.find(&tag)? + tag.len();
    let rest: String = line[start..]
        .chars()
        .take_while(|c| !matches!(c, ',' | '}' | ']' | ' '))
        .collect();
    rest.parse().ok()
}

fn arr_field(line: &str, key: &str) -> Option<Vec<f64>> {
    let tag = format!("\"{key}\": [");
    let start = line.find(&tag)? + tag.len();
    let end = start + line[start..].find(']')?;
    line[start..end]
        .split(',')
        .map(|t| t.trim().parse::<f64>().ok())
        .collect()
}

/// Extract the calibration samples a `bench-json` run archived. One
/// sample per line in the emitted format; lines that don't carry a
/// full sample are skipped, so the parser tolerates the surrounding
/// report structure (and concatenated files). Feature vectors shorter
/// than the current [`N_FEATURES`] — archives written before a feature
/// was appended — are zero-padded (a zero column keeps its seed weight
/// in [`fit`]); vectors *longer* than current (from a newer build) are
/// dropped, since their extractor resolved against features this build
/// cannot interpret.
pub fn samples_from_json(text: &str) -> Vec<Sample> {
    text.lines().filter_map(sample_from_json_line).collect()
}

/// Parse a single archival line into a [`Sample`], or `None` if the
/// line does not carry a full, sane sample. The strict-archive loader
/// (`runtime::artifacts::load_samples_counted_in`) uses this per-line
/// seam to *count* failures on `.jsonl` archives, where every line is
/// supposed to be a sample, while [`samples_from_json`] keeps skipping
/// silently for mixed report files.
pub fn sample_from_json_line(line: &str) -> Option<Sample> {
    let matrix = str_field(line, "matrix")?;
    let plan_id = str_field(line, "plan")?;
    let fv = arr_field(line, "features")?;
    let measured = num_field(line, "measured_secs")?;
    let predicted = num_field(line, "predicted_secs")?;
    if fv.is_empty() || fv.len() > N_FEATURES || !measured.is_finite() || measured <= 0.0 {
        return None;
    }
    let mut features = [0.0; N_FEATURES];
    features[..fv.len()].copy_from_slice(&fv);
    Some(Sample { matrix, plan_id, features, measured_secs: measured, predicted_secs: predicted })
}

/// Render one sample as the archival JSON object (single line — the
/// format [`samples_from_json`] parses).
pub fn sample_to_json(s: &Sample) -> String {
    let feats: Vec<String> = s.features.iter().map(|v| format!("{v:e}")).collect();
    format!(
        "{{\"matrix\": \"{}\", \"plan\": \"{}\", \"features\": [{}], \
         \"measured_secs\": {:e}, \"predicted_secs\": {:e}}}",
        json_escape(&s.matrix),
        json_escape(&s.plan_id),
        feats.join(", "),
        s.measured_secs,
        s.predicted_secs
    )
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            // Keep every sample on one line — the line-oriented parser
            // would otherwise silently drop a sample whose matrix name
            // carried a control character.
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth_samples(w_true: &[f64; N_FEATURES], n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        // Feature magnitudes spanning the real extractor's scales.
        let mag = [1e6, 1e5, 1e6, 1e3, 8.0, 40.0, 1e5, 1e4, 1e5];
        (0..n)
            .map(|i| {
                let mut f = [0.0; N_FEATURES];
                for (fj, m) in f.iter_mut().zip(&mag) {
                    *fj = m * rng.gen_f64_range(0.1, 1.0);
                }
                let measured: f64 = f.iter().zip(w_true).map(|(a, b)| a * b).sum();
                Sample {
                    matrix: format!("m{}", i % 7),
                    plan_id: format!("p{i}"),
                    features: f,
                    measured_secs: measured,
                    predicted_secs: measured,
                }
            })
            .collect()
    }

    /// The ISSUE's planted-parameter property: NNLS over synthetic
    /// samples generated from a known non-negative weight vector must
    /// recover it (within tolerance) — including the zero entries.
    #[test]
    fn nnls_recovers_planted_parameters() {
        let w_true = [1.25e-10, 6.7e-10, 2.5e-10, 1.5e-9, 2.5e-5, 4e-7, 0.0, 3e-9, 4.5e-11];
        let samples = synth_samples(&w_true, 60, 42);
        let seed = CostParams::host_small();
        let fitted = fit(&samples, &seed);
        for (j, (&got, &want)) in fitted.weights.iter().zip(&w_true).enumerate() {
            if want == 0.0 {
                assert!(got.abs() < 1e-13, "w[{j}] = {got:e}, planted 0");
            } else {
                let rel = (got - want).abs() / want;
                assert!(rel < 1e-4, "w[{j}] = {got:e} vs planted {want:e} (rel {rel:e})");
            }
        }
        // And the fitted model predicts the samples near-exactly.
        for s in &samples {
            let pred: f64 =
                s.features.iter().zip(&fitted.weights).map(|(a, b)| a * b).sum();
            assert!((pred - s.measured_secs).abs() / s.measured_secs < 1e-6);
        }
        // Structural shape carried over from the seed.
        assert_eq!(fitted.l2_bytes, seed.l2_bytes);
        assert_eq!(fitted.threads, seed.threads);
    }

    #[test]
    fn absent_features_keep_seed_weights() {
        // Samples that never exercise spawns/syncs/imbalance (a
        // serial-only sweep): those columns must keep the seed values.
        let w_true = [1.25e-10, 6.7e-10, 2.5e-10, 1.5e-9, 0.0, 0.0, 0.0, 0.0, 0.0];
        let mut samples = synth_samples(&w_true, 40, 7);
        for s in &mut samples {
            s.features[4] = 0.0;
            s.features[5] = 0.0;
            s.features[6] = 0.0;
            s.features[7] = 0.0;
            s.features[8] = 0.0;
            s.measured_secs =
                s.features.iter().zip(&w_true).map(|(a, b)| a * b).sum();
        }
        let seed = CostParams::host_large(8);
        let fitted = fit(&samples, &seed);
        assert_eq!(fitted.weights[4], seed.weights[4]);
        assert_eq!(fitted.weights[5], seed.weights[5]);
        assert_eq!(fitted.weights[6], seed.weights[6]);
        assert_eq!(fitted.weights[7], seed.weights[7], "scalar sweeps keep gather_lanes at seed");
        assert_eq!(fitted.weights[8], seed.weights[8], "single-node sweeps keep remote_bytes at seed");
        assert!((fitted.weights[0] - w_true[0]).abs() / w_true[0] < 1e-4);
    }

    #[test]
    fn fit_on_empty_returns_seed() {
        let seed = CostParams::host_small();
        assert_eq!(fit(&[], &seed), seed);
    }

    #[test]
    fn nnls_clamps_negative_coordinates() {
        // Unconstrained LS on this system is exactly (a, b) = (−1, 4);
        // NNLS must land on the boundary optimum (0, 2) instead.
        let xs = vec![
            [1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            [2.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            [3.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        ];
        let y = vec![3.0, 2.0, 1.0];
        let w = nnls(&xs, &y, &[0.0; N_FEATURES]);
        assert!(w.iter().all(|&v| v >= 0.0), "{w:?}");
        assert!(w[0] < 1e-10, "anti-correlated column not clamped: {w:?}");
        assert!((w[1] - 2.0).abs() < 1e-8, "{w:?}");
    }

    #[test]
    fn top1_agreement_counts_per_matrix_winners() {
        let mk = |matrix: &str, plan: &str, f0: f64, measured: f64| Sample {
            matrix: matrix.into(),
            plan_id: plan.into(),
            features: [f0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            measured_secs: measured,
            predicted_secs: f0,
        };
        let w = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        // m1: prediction order (a, b) matches measurement; m2 inverted.
        let samples = vec![
            mk("m1", "a", 1.0, 1.0),
            mk("m1", "b", 2.0, 2.0),
            mk("m2", "a", 1.0, 5.0),
            mk("m2", "b", 2.0, 2.0),
        ];
        assert_eq!(top1_agreement(&samples, &w), (1, 2));
        // A weight vector that ranks b first everywhere: only m2 agrees.
        let w2 = [-1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(top1_agreement(&samples, &w2), (1, 2));
        // Merged archives: duplicate (matrix, plan) samples from two
        // bench records. Predicted picks the first copy of plan a,
        // measured picks the *second* copy of plan a — same plan, so
        // the matrix must still count as agreeing.
        let merged = vec![
            mk("m3", "a", 1.0, 2.0),
            mk("m3", "b", 3.0, 3.0),
            mk("m3", "a", 1.5, 1.9), // second record's copy, a bit faster
            mk("m3", "b", 3.0, 3.1),
        ];
        assert_eq!(top1_agreement(&merged, &w), (1, 1));
        // The recorded baseline ranks by archived predicted_secs (here
        // = features[0], since mk mirrors them): same verdicts as the
        // recording weights themselves.
        assert_eq!(top1_agreement_recorded(&samples), (1, 2));
        assert_eq!(top1_agreement_recorded(&merged), (1, 1));
    }

    #[test]
    fn profile_roundtrip_is_lossless() {
        let p = Profile {
            arch_slug: "host-large".into(),
            l2_bytes: 8e6,
            threads: 8,
            weights: [
                1.2500000000000001e-10,
                2.5e-10,
                1.2447e-10,
                9.999999999999999e-10,
                2.5e-5,
                3.0000000000000004e-7,
                5.5e-13,
                7.250000000000001e-12,
                4.0999999999999997e-11,
            ],
            samples: 123,
        };
        let text = p.render();
        let q = Profile::parse(&text).expect("parse");
        assert_eq!(p, q, "profile round-trip must be bit-lossless");
        // Thread pinning on application.
        let params = q.params_for(4);
        assert_eq!(params.threads, 4);
        assert_eq!(params.weights, p.weights);
        assert_eq!(params.l2_bytes, 8e6);
    }

    #[test]
    fn profile_parse_rejects_garbage() {
        assert!(Profile::parse("").is_err());
        assert!(Profile::parse("arch x\nthreads 2\n").is_err()); // no l2/weights
        let mut ok = Profile::from_params("a", &CostParams::host_small(), 1).render();
        ok.push_str("weight not_a_feature 1.0\n");
        assert!(Profile::parse(&ok).is_err());
        // Unknown keys are tolerated.
        let mut fwd = Profile::from_params("a", &CostParams::host_small(), 1).render();
        fwd.push_str("future_key 42\n");
        assert!(Profile::parse(&fwd).is_ok());
    }

    /// The append-only contract under N_FEATURES growth: a profile
    /// written before a feature existed parses with weight 0 for it,
    /// and an archived sample with a shorter feature vector is
    /// zero-padded rather than dropped.
    #[test]
    fn old_archives_survive_feature_appends() {
        // Drop the last weight line from a rendered profile — what a
        // pre-append profile looks like to post-append code.
        let full = Profile::from_params("host-small", &CostParams::host_small(), 5).render();
        let trimmed: String = full
            .lines()
            .filter(|l| !l.starts_with(&format!("weight {}", FEATURE_NAMES[N_FEATURES - 1])))
            .map(|l| format!("{l}\n"))
            .collect();
        let p = Profile::parse(&trimmed).expect("pre-append profile must parse");
        assert_eq!(p.weights[N_FEATURES - 1], 0.0);
        assert_eq!(p.weights[0], CostParams::host_small().weights[0]);
        // A sample line with a shorter feature array: zero-padded.
        let line = "{\"matrix\": \"m\", \"plan\": \"csr.row.serial\", \
                    \"features\": [1e6, 2e5], \"measured_secs\": 1e-4, \
                    \"predicted_secs\": 2e-4}";
        let got = samples_from_json(line);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].features[0], 1e6);
        assert_eq!(got[0].features[1], 2e5);
        assert!(got[0].features[2..].iter().all(|&f| f == 0.0));
    }

    #[test]
    fn sample_json_roundtrip() {
        let s = Sample {
            matrix: "Raj1 \"scaled\"".into(),
            plan_id: "csr.row.par4".into(),
            features: [1.5e6, 2.5e4, 0.0, 1e3, 4.0, 0.0, 3.3e5, 1.2e4, 2.1e5],
            measured_secs: 1.25e-4,
            predicted_secs: 1.5e-4,
        };
        let line = sample_to_json(&s);
        let parsed = samples_from_json(&line);
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0], s);
        // Embedded in report noise + multiple lines.
        let noisy = format!(
            "{{\n  \"kernel\": \"SPMV\",\n  \"samples\": [\n      {},\n      {}\n  ]\n}}\n",
            line,
            sample_to_json(&Sample { matrix: "b".into(), ..s.clone() })
        );
        assert_eq!(samples_from_json(&noisy).len(), 2);
        // Garbage lines are skipped, not fatal.
        assert!(samples_from_json("{\"matrix\": \"x\"}\nnot json\n").is_empty());
    }
}
