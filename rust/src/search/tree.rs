//! Transformation-tree enumeration (paper §6.3, Fig 10): starting from
//! the minimal forelem representation of a kernel, walk every legal
//! sequence of transformations, concretize every materialized node, and
//! collect the resulting *variants* (executables) and *distinct data
//! structures* — reproducing the paper's "130 implementations / 25 data
//! structures" exploration programmatically.

use std::collections::{BTreeMap, HashSet};

use crate::baselines::Kernel;
use crate::concretize::{self, Plan};
use crate::forelem::ir::{ChainState, NStarMat, Orth};
use crate::transforms::{BlockStep, Step};

/// One automatically instantiated routine + data structure.
#[derive(Clone, Debug)]
pub struct Variant {
    /// Stable id within the enumeration, e.g. "v017".
    pub id: String,
    /// Human-readable derivation, e.g.
    /// "orthogonalize(row) → materialize(dep) → split → nstar(padded)".
    pub derivation: String,
    pub state: ChainState,
    pub plan: Plan,
}

impl Variant {
    /// Short display name: layout + traversal.
    pub fn name(&self) -> String {
        format!("{:?}/{:?}", self.plan.layout, self.plan.traversal)
    }
}

/// The step universe the tree explores. `Localize`/`Hisr` are excluded:
/// they never change the concretized layout, so including them only
/// duplicates variants (they are demonstrated in `examples/`).
fn universe() -> Vec<Step> {
    vec![
        Step::Orthogonalize(Orth::Row),
        Step::Orthogonalize(Orth::Col),
        Step::Orthogonalize(Orth::RowCol),
        Step::Orthogonalize(Orth::Diag),
        Step::Materialize,
        Step::Split,
        Step::NStar(NStarMat::Padded),
        Step::NStar(NStarMat::Exact),
        Step::NStarSort,
        Step::Interchange,
        Step::DimReduce,
        Step::Block(BlockStep::Tile2x2),
        Step::Block(BlockStep::Tile3x3),
        Step::Block(BlockStep::Tile4x4),
        Step::Block(BlockStep::FillCutoff),
        Step::Block(BlockStep::RowSlice32),
        Step::Block(BlockStep::RowSlice128),
    ]
}

/// Result of the enumeration.
pub struct Tree {
    pub kernel: Kernel,
    /// All distinct executables (variant = distinct concretization plan).
    pub variants: Vec<Variant>,
    /// Number of explored IR nodes (including non-concretizable "tmp"
    /// stages, paper Fig 10's `tmp*` nodes).
    pub nodes_explored: usize,
    /// Number of concretizable chains before executable dedup — the
    /// paper's "130 implementations" counts chains at this granularity.
    pub chains_concretized: usize,
    /// Distinct generated data structures (layouts).
    pub distinct_layouts: usize,
}

/// Enumerate the full tree for a kernel.
pub fn enumerate(kernel: Kernel) -> Tree {
    let steps = universe();
    let mut seen_states: HashSet<String> = HashSet::new();
    let mut seen_variants: HashSet<Plan> = HashSet::new();
    let mut variants: Vec<Variant> = Vec::new();
    let mut nodes = 0usize;
    let mut chains = 0usize;

    // Iterative DFS over chain states.
    let mut stack: Vec<ChainState> = vec![ChainState::initial(kernel)];
    while let Some(state) = stack.pop() {
        let state_key = format!("{} | {:?}", state.layout_key(), state.history);
        // Dedup purely on the *semantic* state (layout_key + flags that
        // affect future legality), not history, to bound the walk.
        let sem_key = format!(
            "{} mat={:?} hisr={}",
            state.layout_key(),
            state.materialized,
            state.hisr
        );
        if !seen_states.insert(sem_key) {
            continue;
        }
        let _ = state_key;
        nodes += 1;

        // Concretize if possible: each plan is an executable variant.
        if let Ok(plans) = concretize::plans(&state) {
            for plan in plans {
                if !concretize::supports(&plan, kernel) {
                    continue;
                }
                chains += 1;
                if seen_variants.insert(plan) {
                    let id = format!("v{:03}", variants.len() + 1);
                    variants.push(Variant {
                        id,
                        derivation: state.history.join(" \u{2192} "),
                        state: state.clone(),
                        plan,
                    });
                }
            }
        }

        // Expand children.
        for step in &steps {
            let mut child = state.clone();
            if step.apply(&mut child).is_ok() {
                stack.push(child);
            }
        }
    }

    // Deterministic order: by derivation string.
    variants.sort_by(|a, b| a.derivation.cmp(&b.derivation));
    for (i, v) in variants.iter_mut().enumerate() {
        v.id = format!("v{:03}", i + 1);
    }
    let distinct_layouts = variants
        .iter()
        .map(|v| format!("{:?}", v.plan.layout))
        .collect::<HashSet<_>>()
        .len();
    Tree { kernel, variants, nodes_explored: nodes, chains_concretized: chains, distinct_layouts }
}

/// Summarize the tree as (layout → variant count), for the Fig 10 report.
pub fn layout_histogram(tree: &Tree) -> BTreeMap<String, usize> {
    let mut h = BTreeMap::new();
    for v in &tree.variants {
        *h.entry(format!("{:?}", v.plan.layout)).or_insert(0) += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmv_tree_is_rich() {
        let t = enumerate(Kernel::Spmv);
        // The paper reports 130 executables / 25 structures for SpMM×k;
        // our deduplicated tree must be the same order of magnitude.
        assert!(t.variants.len() >= 15, "only {} variants", t.variants.len());
        assert!(t.distinct_layouts >= 12, "only {} layouts", t.distinct_layouts);
        assert!(t.nodes_explored > t.variants.len());
    }

    #[test]
    fn spmv_tree_contains_named_formats() {
        let t = enumerate(Kernel::Spmv);
        let names: HashSet<String> =
            t.variants.iter().map(|v| v.plan.layout.literature_name().to_string()).collect();
        for want in [
            "Compressed Row Storage (CSR)",
            "Compressed Column Storage (CCS)",
            "ITPACK/ELLPACK (column-major)",
            "Jagged Diagonal Storage (JDS)",
            "coordinate (COO)",
            "Blocked CSR (BCSR)",
            "hybrid ELL+COO",
            "diagonal storage (DIA)",
        ] {
            assert!(names.contains(want), "missing {want}; have {names:?}");
        }
    }

    #[test]
    fn trsv_tree_is_restricted() {
        let spmv = enumerate(Kernel::Spmv);
        let trsv = enumerate(Kernel::Trsv);
        assert!(trsv.variants.len() < spmv.variants.len());
        // no JDS/interchange variants for TrSv
        assert!(trsv.variants.iter().all(|v| !v.state.interchanged && !v.state.sorted));
    }

    #[test]
    fn ids_unique_and_ordered() {
        let t = enumerate(Kernel::Spmm);
        let ids: HashSet<&String> = t.variants.iter().map(|v| &v.id).collect();
        assert_eq!(ids.len(), t.variants.len());
        assert_eq!(t.variants[0].id, "v001");
    }

    #[test]
    fn enumeration_is_deterministic() {
        let a = enumerate(Kernel::Spmv);
        let b = enumerate(Kernel::Spmv);
        let da: Vec<&String> = a.variants.iter().map(|v| &v.derivation).collect();
        let db: Vec<&String> = b.variants.iter().map(|v| &v.derivation).collect();
        assert_eq!(da, db);
    }
}
