//! Transformation-tree enumeration (paper §6.3, Fig 10): starting from
//! the minimal forelem representation of a kernel, walk every legal
//! sequence of transformations, concretize every materialized node,
//! cross the concretizable chains with the [`PlanSpace`]'s schedules,
//! and return the surviving [`Plan`]s *cost-ranked* — stage 1 of the
//! predict→measure planner pipeline (see `search::plan`).
//!
//! One entry point serves every caller: `enumerate(kernel, &space)`
//! with `PlanSpace::serial_only()` reproduces the paper's single-core
//! Layout × Traversal tree exactly (same plan set; order is by
//! predicted cost); `PlanSpace::host(..)` adds the schedule axis.

use std::collections::{BTreeMap, HashSet};

use crate::baselines::Kernel;
use crate::concretize::{self, Plan as ExecPlan};
use crate::search::cost;
use crate::search::plan::{Plan, PlanSpace};
use crate::transforms::{BlockStep, Step};

use crate::forelem::ir::{ChainState, NStarMat, Orth};

/// The step universe the tree explores. `Localize`/`Hisr` are excluded:
/// they never change the concretized layout, so including them only
/// duplicates variants (they are demonstrated in `examples/`).
fn universe() -> Vec<Step> {
    vec![
        Step::Orthogonalize(Orth::Row),
        Step::Orthogonalize(Orth::Col),
        Step::Orthogonalize(Orth::RowCol),
        Step::Orthogonalize(Orth::Diag),
        Step::Materialize,
        Step::Split,
        Step::NStar(NStarMat::Padded),
        Step::NStar(NStarMat::Exact),
        Step::NStarSort,
        Step::Interchange,
        Step::DimReduce,
        Step::Block(BlockStep::Tile2x2),
        Step::Block(BlockStep::Tile3x3),
        Step::Block(BlockStep::Tile4x4),
        Step::Block(BlockStep::FillCutoff),
        Step::Block(BlockStep::RowSlice32),
        Step::Block(BlockStep::RowSlice128),
    ]
}

/// Result of the enumeration.
pub struct Tree {
    pub kernel: Kernel,
    /// All distinct executables, ranked by predicted cost on the
    /// space's ranking statistics (ascending; ties by stable id).
    pub plans: Vec<Plan>,
    /// Number of explored IR nodes (including non-concretizable "tmp"
    /// stages, paper Fig 10's `tmp*` nodes).
    pub nodes_explored: usize,
    /// Number of concretizable chains before executable dedup — the
    /// paper's "130 implementations" counts chains at this granularity.
    pub chains_concretized: usize,
    /// Distinct generated data structures (layouts).
    pub distinct_layouts: usize,
}

/// Enumerate the full plan space for a kernel: DFS over the chain
/// states, concretize, cross with the space's schedules, prune illegal
/// (layout, traversal, schedule, kernel) combinations, rank by the
/// analytic cost model.
pub fn enumerate(kernel: Kernel, space: &PlanSpace) -> Tree {
    let steps = universe();
    let mut seen_states: HashSet<String> = HashSet::new();
    let mut seen_execs: HashSet<ExecPlan> = HashSet::new();
    let mut serial: Vec<(ChainState, String, ExecPlan)> = Vec::new();
    let mut nodes = 0usize;
    let mut chains = 0usize;

    // Iterative DFS over chain states.
    let mut stack: Vec<ChainState> = vec![ChainState::initial(kernel)];
    while let Some(state) = stack.pop() {
        // Dedup purely on the *semantic* state (layout_key + flags that
        // affect future legality), not history, to bound the walk.
        let sem_key = format!(
            "{} mat={:?} hisr={}",
            state.layout_key(),
            state.materialized,
            state.hisr
        );
        if !seen_states.insert(sem_key) {
            continue;
        }
        nodes += 1;

        // Concretize if possible: each serial plan is an executable.
        if let Ok(execs) = concretize::plans(&state) {
            for exec in execs {
                if !concretize::supports(&exec, kernel) {
                    continue;
                }
                chains += 1;
                if seen_execs.insert(exec) {
                    serial.push((state.clone(), state.history.join(" \u{2192} "), exec));
                }
            }
        }

        // Expand children.
        for step in &steps {
            let mut child = state.clone();
            if step.apply(&mut child).is_ok() {
                stack.push(child);
            }
        }
    }

    // Cross the serial tree with the space's schedules and vector
    // widths, pruning illegal combinations (TrSv stays Serial and
    // scalar; only row-partitionable layouts parallelize; only CSR
    // SpMV tiles; `lane_legal` gates widths by format).
    let mut plans: Vec<Plan> = Vec::new();
    for (state, derivation, exec) in &serial {
        for &schedule in &space.schedules {
            let scheduled = exec.with_schedule(schedule);
            if !concretize::supports(&scheduled, kernel) {
                continue;
            }
            for &lanes in &space.lanes {
                let widened = scheduled.with_lanes(lanes);
                if !concretize::supports(&widened, kernel) {
                    continue;
                }
                let mut derivation = if schedule.is_serial() {
                    derivation.clone()
                } else {
                    format!("{derivation} \u{2192} schedule({})", schedule.label())
                };
                if lanes > 1 {
                    derivation = format!("{derivation} \u{2192} vectorize(v{lanes})");
                }
                plans.push(Plan::new(state.clone(), derivation, widened));
            }
        }
    }

    // Cost-rank: predicted seconds on the space's reference statistics,
    // stable ids as the deterministic tiebreak.
    let stats = space.ranking_stats();
    let mut scored: Vec<(f64, Plan)> = plans
        .into_iter()
        .map(|p| (cost::predict(kernel, space.dense_k, &p.exec, &stats, &space.params), p))
        .collect();
    scored.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.id.cmp(&b.1.id))
    });
    let plans: Vec<Plan> = scored.into_iter().map(|(_, p)| p).collect();

    let distinct_layouts = plans
        .iter()
        .map(|p| format!("{:?}", p.exec.layout))
        .collect::<HashSet<_>>()
        .len();
    Tree { kernel, plans, nodes_explored: nodes, chains_concretized: chains, distinct_layouts }
}

/// Summarize the tree as (layout → plan count), for the Fig 10 report.
pub fn layout_histogram(tree: &Tree) -> BTreeMap<String, usize> {
    let mut h = BTreeMap::new();
    for p in &tree.plans {
        *h.entry(format!("{:?}", p.exec.layout)).or_insert(0) += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concretize::Layout;

    #[test]
    fn spmv_tree_is_rich() {
        let t = enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        // The paper reports 130 executables / 25 structures for SpMM×k;
        // our deduplicated tree must be the same order of magnitude.
        assert!(t.plans.len() >= 15, "only {} plans", t.plans.len());
        assert!(t.distinct_layouts >= 12, "only {} layouts", t.distinct_layouts);
        assert!(t.nodes_explored > t.plans.len());
    }

    #[test]
    fn spmv_tree_contains_named_formats() {
        let t = enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let names: HashSet<String> =
            t.plans.iter().map(|p| p.exec.layout.literature_name().to_string()).collect();
        for want in [
            "Compressed Row Storage (CSR)",
            "Compressed Column Storage (CCS)",
            "ITPACK/ELLPACK (column-major)",
            "Jagged Diagonal Storage (JDS)",
            "coordinate (COO)",
            "Blocked CSR (BCSR)",
            "hybrid ELL+COO",
            "diagonal storage (DIA)",
            "Sliced ELLPACK (SELL)",
            "row-sorted Sliced ELLPACK (SELL-\u{3c3})",
        ] {
            assert!(names.contains(want), "missing {want}; have {names:?}");
        }
        // The SELL-σ chain (block(slice) → materialize → nstar_sort)
        // concretizes with its content-derived id.
        assert!(t.plans.iter().any(|p| p.id == "sell32s256.slice.serial"));
        assert!(t.plans.iter().any(|p| p.id == "sell128s1024.slice.serial"));
    }

    #[test]
    fn trsv_tree_is_restricted() {
        let spmv = enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let trsv = enumerate(Kernel::Trsv, &PlanSpace::serial_only());
        assert!(trsv.plans.len() < spmv.plans.len());
        // no JDS/interchange variants for TrSv
        assert!(trsv.plans.iter().all(|p| !p.state.interchanged && !p.state.sorted));
    }

    #[test]
    fn ids_unique_and_stable() {
        let t = enumerate(Kernel::Spmm, &PlanSpace::serial_only());
        let ids: HashSet<&String> = t.plans.iter().map(|p| &p.id).collect();
        assert_eq!(ids.len(), t.plans.len());
        // Content-derived: the CSR row-wise serial plan keeps its id
        // no matter where the ranking puts it.
        assert!(t.plans.iter().any(|p| p.id == "csr.row.serial"));
    }

    #[test]
    fn scheduled_space_extends_serial_tree() {
        let serial = enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let t = enumerate(Kernel::Spmv, &PlanSpace::host(4, 4096));
        // Every serial plan survives, plus the scheduled/widened ones.
        let serial_in_t = t
            .plans
            .iter()
            .filter(|p| p.exec.schedule.is_serial() && p.exec.lanes == 1)
            .count();
        assert_eq!(serial_in_t, serial.plans.len());
        assert!(t.plans.len() > serial.plans.len());
        // CSR gets all four schedules (RowWise CSR SpMV tiles).
        let csr: Vec<_> =
            t.plans.iter().filter(|p| p.exec.layout == Layout::Csr).collect();
        assert!(csr.len() >= 4, "CSR schedules missing: {:?}", csr.len());
        // Scheduled derivations record the schedule step.
        for p in &t.plans {
            if !p.exec.schedule.is_serial() {
                assert!(p.derivation.contains("schedule("), "{}", p.derivation);
            }
        }
        // Ids stay unique.
        let ids: HashSet<&String> = t.plans.iter().map(|p| &p.id).collect();
        assert_eq!(ids.len(), t.plans.len());
    }

    #[test]
    fn scheduled_space_trsv_adds_only_level_plans() {
        let t = enumerate(Kernel::Trsv, &PlanSpace::host(8, 1024));
        assert!(!t.plans.is_empty());
        // TrSv reschedules onto level sets for SoA CSR/CSC only —
        // never tiles, never parallelizes the other traversals.
        let non_serial: Vec<_> =
            t.plans.iter().filter(|p| !p.exec.schedule.is_serial()).collect();
        assert_eq!(non_serial.len(), 2, "expected csr+csc level plans: {non_serial:?}");
        for p in &non_serial {
            assert!(matches!(p.exec.schedule, crate::concretize::Schedule::Parallel { .. }));
            assert!(matches!(p.exec.layout, Layout::Csr | Layout::Csc), "{:?}", p.exec);
            assert!(p.derivation.contains("schedule("), "{}", p.derivation);
        }
        assert!(t.plans.iter().any(|p| p.id == "csr.row.par8"));
        assert!(t.plans.iter().any(|p| p.id == "csc.colscat.par8"));
        let serial = enumerate(Kernel::Trsv, &PlanSpace::serial_only());
        assert_eq!(t.plans.len(), serial.plans.len() + 2);
    }

    #[test]
    fn host_space_crosses_the_lane_axis() {
        let t = enumerate(Kernel::Spmv, &PlanSpace::host(4, 4096));
        // CSR row-wise widens under serial and parallel schedules.
        assert!(t.plans.iter().any(|p| p.id == "csr.row.serial.v8"));
        assert!(t.plans.iter().any(|p| p.id == "csr.row.par4.v4"));
        // SELL-σ widens when the slice height divides (32 % 8 == 0).
        assert!(t.plans.iter().any(|p| p.id == "sell32s256.slice.serial.v8"));
        // Tiled schedules never widen; wide plans record the step.
        for p in &t.plans {
            if p.exec.lanes > 1 {
                assert!(p.exec.schedule.is_serial()
                    || matches!(p.exec.schedule, crate::concretize::Schedule::Parallel { .. }));
                assert!(p.derivation.contains("vectorize(v"), "{}", p.derivation);
            }
        }
        // Ids stay unique across the widened space.
        let ids: HashSet<&String> = t.plans.iter().map(|p| &p.id).collect();
        assert_eq!(ids.len(), t.plans.len());
        // The lane axis never reaches TrSv.
        let trsv = enumerate(Kernel::Trsv, &PlanSpace::host(4, 4096));
        assert!(trsv.plans.iter().all(|p| p.exec.lanes == 1));
    }

    #[test]
    fn serial_only_space_reproduces_paper_tree() {
        let a = enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let b = enumerate(Kernel::Spmv, &PlanSpace::host(4, 4096));
        // The scalar serial subset of the scheduled space is exactly
        // the serial-only tree (same execution tuples).
        let mut pa: Vec<ExecPlan> = a.plans.iter().map(|p| p.exec).collect();
        let mut pb: Vec<ExecPlan> = b
            .plans
            .iter()
            .filter(|p| p.exec.schedule.is_serial() && p.exec.lanes == 1)
            .map(|p| p.exec)
            .collect();
        let key = |e: &ExecPlan| format!("{e:?}");
        pa.sort_by_key(key);
        pb.sort_by_key(key);
        assert_eq!(pa, pb);
    }

    #[test]
    fn plans_are_cost_ranked() {
        let space = PlanSpace::serial_only();
        let t = enumerate(Kernel::Spmv, &space);
        let stats = space.ranking_stats();
        let scores: Vec<f64> = t
            .plans
            .iter()
            .map(|p| cost::predict(Kernel::Spmv, space.dense_k, &p.exec, &stats, &space.params))
            .collect();
        for w in scores.windows(2) {
            assert!(w[0] <= w[1], "plans not cost-ranked: {w:?}");
        }
        // Ranking against concrete statistics also holds.
        let banded = crate::matrix::MatrixStats::synthetic(2000, 2000, 7.0, 1.0, 9, 4);
        let ranked = PlanSpace::serial_only().with_rank_stats(banded);
        let t2 = enumerate(Kernel::Spmv, &ranked);
        assert_eq!(t2.plans.len(), t.plans.len());
    }

    #[test]
    fn enumeration_is_deterministic() {
        let a = enumerate(Kernel::Spmv, &PlanSpace::host(3, 512));
        let b = enumerate(Kernel::Spmv, &PlanSpace::host(3, 512));
        let ia: Vec<&String> = a.plans.iter().map(|p| &p.id).collect();
        let ib: Vec<&String> = b.plans.iter().map(|p| &p.id).collect();
        assert_eq!(ia, ib);
        let da: Vec<&String> = a.plans.iter().map(|p| &p.derivation).collect();
        let db: Vec<&String> = b.plans.iter().map(|p| &p.derivation).collect();
        assert_eq!(da, db);
    }
}
