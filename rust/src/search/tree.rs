//! Transformation-tree enumeration (paper §6.3, Fig 10): starting from
//! the minimal forelem representation of a kernel, walk every legal
//! sequence of transformations, concretize every materialized node, and
//! collect the resulting *variants* (executables) and *distinct data
//! structures* — reproducing the paper's "130 implementations / 25 data
//! structures" exploration programmatically.

use std::collections::{BTreeMap, HashSet};

use crate::baselines::Kernel;
use crate::concretize::{self, Plan, Schedule};
use crate::forelem::ir::{ChainState, NStarMat, Orth};
use crate::transforms::{BlockStep, Step};

/// One automatically instantiated routine + data structure.
#[derive(Clone, Debug)]
pub struct Variant {
    /// Stable id within the enumeration, e.g. "v017".
    pub id: String,
    /// Human-readable derivation, e.g.
    /// "orthogonalize(row) → materialize(dep) → split → nstar(padded)".
    pub derivation: String,
    pub state: ChainState,
    pub plan: Plan,
}

impl Variant {
    /// Short display name: layout + traversal (+ schedule when not
    /// serial).
    pub fn name(&self) -> String {
        if self.plan.schedule.is_serial() {
            format!("{:?}/{:?}", self.plan.layout, self.plan.traversal)
        } else {
            format!(
                "{:?}/{:?}@{}",
                self.plan.layout,
                self.plan.traversal,
                self.plan.schedule.label()
            )
        }
    }
}

/// The pool of schedules `enumerate_scheduled` crosses with the serial
/// plan space. `serial_only()` reproduces the paper's single-core
/// tables exactly; `host(..)` adds the parallel / cache-blocked axis.
#[derive(Clone, Debug)]
pub struct SchedulePool {
    pub schedules: Vec<Schedule>,
}

impl SchedulePool {
    /// Only `Serial` — the paper's measurement protocol.
    pub fn serial_only() -> Self {
        SchedulePool { schedules: vec![Schedule::Serial] }
    }

    /// Serial + parallel + tiled + both, for a host with `threads`
    /// workers and an L2 that holds `x_block` doubles of `x` band.
    pub fn host(threads: usize, x_block: usize) -> Self {
        SchedulePool {
            schedules: vec![
                Schedule::Serial,
                Schedule::Parallel { threads },
                Schedule::Tiled { x_block },
                Schedule::ParallelTiled { threads, x_block },
            ],
        }
    }
}

/// The step universe the tree explores. `Localize`/`Hisr` are excluded:
/// they never change the concretized layout, so including them only
/// duplicates variants (they are demonstrated in `examples/`).
fn universe() -> Vec<Step> {
    vec![
        Step::Orthogonalize(Orth::Row),
        Step::Orthogonalize(Orth::Col),
        Step::Orthogonalize(Orth::RowCol),
        Step::Orthogonalize(Orth::Diag),
        Step::Materialize,
        Step::Split,
        Step::NStar(NStarMat::Padded),
        Step::NStar(NStarMat::Exact),
        Step::NStarSort,
        Step::Interchange,
        Step::DimReduce,
        Step::Block(BlockStep::Tile2x2),
        Step::Block(BlockStep::Tile3x3),
        Step::Block(BlockStep::Tile4x4),
        Step::Block(BlockStep::FillCutoff),
        Step::Block(BlockStep::RowSlice32),
        Step::Block(BlockStep::RowSlice128),
    ]
}

/// Result of the enumeration.
pub struct Tree {
    pub kernel: Kernel,
    /// All distinct executables (variant = distinct concretization plan).
    pub variants: Vec<Variant>,
    /// Number of explored IR nodes (including non-concretizable "tmp"
    /// stages, paper Fig 10's `tmp*` nodes).
    pub nodes_explored: usize,
    /// Number of concretizable chains before executable dedup — the
    /// paper's "130 implementations" counts chains at this granularity.
    pub chains_concretized: usize,
    /// Distinct generated data structures (layouts).
    pub distinct_layouts: usize,
}

/// Enumerate the full tree for a kernel.
pub fn enumerate(kernel: Kernel) -> Tree {
    let steps = universe();
    let mut seen_states: HashSet<String> = HashSet::new();
    let mut seen_variants: HashSet<Plan> = HashSet::new();
    let mut variants: Vec<Variant> = Vec::new();
    let mut nodes = 0usize;
    let mut chains = 0usize;

    // Iterative DFS over chain states.
    let mut stack: Vec<ChainState> = vec![ChainState::initial(kernel)];
    while let Some(state) = stack.pop() {
        let state_key = format!("{} | {:?}", state.layout_key(), state.history);
        // Dedup purely on the *semantic* state (layout_key + flags that
        // affect future legality), not history, to bound the walk.
        let sem_key = format!(
            "{} mat={:?} hisr={}",
            state.layout_key(),
            state.materialized,
            state.hisr
        );
        if !seen_states.insert(sem_key) {
            continue;
        }
        let _ = state_key;
        nodes += 1;

        // Concretize if possible: each plan is an executable variant.
        if let Ok(plans) = concretize::plans(&state) {
            for plan in plans {
                if !concretize::supports(&plan, kernel) {
                    continue;
                }
                chains += 1;
                if seen_variants.insert(plan) {
                    let id = format!("v{:03}", variants.len() + 1);
                    variants.push(Variant {
                        id,
                        derivation: state.history.join(" \u{2192} "),
                        state: state.clone(),
                        plan,
                    });
                }
            }
        }

        // Expand children.
        for step in &steps {
            let mut child = state.clone();
            if step.apply(&mut child).is_ok() {
                stack.push(child);
            }
        }
    }

    // Deterministic order: by derivation string.
    variants.sort_by(|a, b| a.derivation.cmp(&b.derivation));
    for (i, v) in variants.iter_mut().enumerate() {
        v.id = format!("v{:03}", i + 1);
    }
    let distinct_layouts = variants
        .iter()
        .map(|v| format!("{:?}", v.plan.layout))
        .collect::<HashSet<_>>()
        .len();
    Tree { kernel, variants, nodes_explored: nodes, chains_concretized: chains, distinct_layouts }
}

/// Enumerate the tree, then cross every serial variant with the pool's
/// schedules, pruning illegal (layout, schedule, kernel) triples via
/// `concretize::supports` (TrSv stays `Serial`; only row-partitionable
/// layouts parallelize; only CSR SpMV tiles). Ids are reassigned so the
/// result is a self-consistent `Tree` whose variant space is
/// Layout × Traversal × Schedule.
pub fn enumerate_scheduled(kernel: Kernel, pool: &SchedulePool) -> Tree {
    let base = enumerate(kernel);
    let mut variants: Vec<Variant> = Vec::new();
    for v in &base.variants {
        for &schedule in &pool.schedules {
            let plan = v.plan.with_schedule(schedule);
            if !concretize::supports(&plan, kernel) {
                continue;
            }
            let derivation = if schedule.is_serial() {
                v.derivation.clone()
            } else {
                format!("{} \u{2192} schedule({})", v.derivation, schedule.label())
            };
            variants.push(Variant {
                id: String::new(),
                derivation,
                state: v.state.clone(),
                plan,
            });
        }
    }
    variants.sort_by(|a, b| a.derivation.cmp(&b.derivation));
    for (i, v) in variants.iter_mut().enumerate() {
        v.id = format!("v{:03}", i + 1);
    }
    let distinct_layouts = variants
        .iter()
        .map(|v| format!("{:?}", v.plan.layout))
        .collect::<HashSet<_>>()
        .len();
    Tree {
        kernel,
        variants,
        nodes_explored: base.nodes_explored,
        chains_concretized: base.chains_concretized,
        distinct_layouts,
    }
}

/// Summarize the tree as (layout → variant count), for the Fig 10 report.
pub fn layout_histogram(tree: &Tree) -> BTreeMap<String, usize> {
    let mut h = BTreeMap::new();
    for v in &tree.variants {
        *h.entry(format!("{:?}", v.plan.layout)).or_insert(0) += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmv_tree_is_rich() {
        let t = enumerate(Kernel::Spmv);
        // The paper reports 130 executables / 25 structures for SpMM×k;
        // our deduplicated tree must be the same order of magnitude.
        assert!(t.variants.len() >= 15, "only {} variants", t.variants.len());
        assert!(t.distinct_layouts >= 12, "only {} layouts", t.distinct_layouts);
        assert!(t.nodes_explored > t.variants.len());
    }

    #[test]
    fn spmv_tree_contains_named_formats() {
        let t = enumerate(Kernel::Spmv);
        let names: HashSet<String> =
            t.variants.iter().map(|v| v.plan.layout.literature_name().to_string()).collect();
        for want in [
            "Compressed Row Storage (CSR)",
            "Compressed Column Storage (CCS)",
            "ITPACK/ELLPACK (column-major)",
            "Jagged Diagonal Storage (JDS)",
            "coordinate (COO)",
            "Blocked CSR (BCSR)",
            "hybrid ELL+COO",
            "diagonal storage (DIA)",
        ] {
            assert!(names.contains(want), "missing {want}; have {names:?}");
        }
    }

    #[test]
    fn trsv_tree_is_restricted() {
        let spmv = enumerate(Kernel::Spmv);
        let trsv = enumerate(Kernel::Trsv);
        assert!(trsv.variants.len() < spmv.variants.len());
        // no JDS/interchange variants for TrSv
        assert!(trsv.variants.iter().all(|v| !v.state.interchanged && !v.state.sorted));
    }

    #[test]
    fn ids_unique_and_ordered() {
        let t = enumerate(Kernel::Spmm);
        let ids: HashSet<&String> = t.variants.iter().map(|v| &v.id).collect();
        assert_eq!(ids.len(), t.variants.len());
        assert_eq!(t.variants[0].id, "v001");
    }

    #[test]
    fn scheduled_tree_extends_serial_tree() {
        let serial = enumerate(Kernel::Spmv);
        let pool = SchedulePool::host(4, 4096);
        let t = enumerate_scheduled(Kernel::Spmv, &pool);
        // Every serial variant survives, plus the scheduled ones.
        let serial_in_t =
            t.variants.iter().filter(|v| v.plan.schedule.is_serial()).count();
        assert_eq!(serial_in_t, serial.variants.len());
        assert!(t.variants.len() > serial.variants.len());
        // CSR gets all four schedules (RowWise CSR SpMV tiles).
        let csr: Vec<_> = t
            .variants
            .iter()
            .filter(|v| v.plan.layout == concretize::Layout::Csr)
            .collect();
        assert!(csr.len() >= 4, "CSR schedules missing: {:?}", csr.len());
        // Scheduled derivations record the schedule step.
        for v in &t.variants {
            if !v.plan.schedule.is_serial() {
                assert!(v.derivation.contains("schedule("), "{}", v.derivation);
            }
        }
        // Ids stay unique.
        let ids: HashSet<&String> = t.variants.iter().map(|v| &v.id).collect();
        assert_eq!(ids.len(), t.variants.len());
    }

    #[test]
    fn scheduled_tree_trsv_stays_serial() {
        let pool = SchedulePool::host(8, 1024);
        let t = enumerate_scheduled(Kernel::Trsv, &pool);
        assert!(!t.variants.is_empty());
        assert!(t.variants.iter().all(|v| v.plan.schedule.is_serial()));
        let serial = enumerate(Kernel::Trsv);
        assert_eq!(t.variants.len(), serial.variants.len());
    }

    #[test]
    fn serial_only_pool_reproduces_paper_tree() {
        let a = enumerate(Kernel::Spmv);
        let b = enumerate_scheduled(Kernel::Spmv, &SchedulePool::serial_only());
        assert_eq!(a.variants.len(), b.variants.len());
        let pa: Vec<_> = a.variants.iter().map(|v| v.plan).collect();
        let pb: Vec<_> = b.variants.iter().map(|v| v.plan).collect();
        assert_eq!(pa, pb);
    }

    #[test]
    fn enumeration_is_deterministic() {
        let a = enumerate(Kernel::Spmv);
        let b = enumerate(Kernel::Spmv);
        let da: Vec<&String> = a.variants.iter().map(|v| &v.derivation).collect();
        let db: Vec<&String> = b.variants.iter().map(|v| &v.derivation).collect();
        assert_eq!(da, db);
    }
}
