//! Search-space machinery: the predict→measure planner pipeline.
//!
//! `tree` enumerates the transformation tree (Fig 10) into cost-ranked
//! first-class plans (`plan::Plan`); `cost` is the analytic model that
//! ranks them (a fittable `FeatureVec · weights` form); `calibrate`
//! closes the predict→measure→refit loop (NNLS fit of the weights from
//! archived samples, persisted as per-machine profiles); `coverage` is
//! the coverage metric (§6.4.4); `select` picks per-matrix best triples
//! and per-architecture all-round kernels (§6.4.5).

pub mod calibrate;
pub mod cost;
pub mod coverage;
pub mod plan;
pub mod select;
pub mod tree;

pub use calibrate::Profile;
pub use cost::{CostParams, FeatureVec};
pub use coverage::Measurements;
pub use plan::{Plan, PlanSpace};
pub use tree::{enumerate, Tree};
