//! Search-space machinery: transformation-tree enumeration (Fig 10),
//! the coverage metric (§6.4.4) and per-architecture all-round kernel
//! selection (§6.4.5).

pub mod coverage;
pub mod select;
pub mod tree;

pub use coverage::Measurements;
pub use tree::{enumerate, enumerate_scheduled, SchedulePool, Tree, Variant};
