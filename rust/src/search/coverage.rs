//! The coverage metric (paper §6.4.4).
//!
//! Given measured execution times for a set of routines `R` over a set of
//! matrices `M`: the *top group* `T(m)` for a matrix m holds the routines
//! within `t%` of the best time `b(m)`; the *weight* of a routine is the
//! number of matrices for which it is in the top group; the *coverage*
//! is the maximal weight. Coverage of 100% at small t means one routine
//! is near-optimal everywhere; the paper shows libraries need large t
//! for that, while generated variants do not.

/// A routine × matrix timing table (seconds; `times[r][m]`).
#[derive(Clone, Debug)]
pub struct Measurements {
    pub routines: Vec<String>,
    pub matrices: Vec<String>,
    pub times: Vec<Vec<f64>>,
}

impl Measurements {
    pub fn new(routines: Vec<String>, matrices: Vec<String>) -> Self {
        let times = vec![vec![f64::NAN; matrices.len()]; routines.len()];
        Measurements { routines, matrices, times }
    }

    pub fn set(&mut self, routine: usize, matrix: usize, t: f64) {
        self.times[routine][matrix] = t;
    }

    /// Validate: every cell filled with a positive finite time.
    pub fn validate(&self) -> Result<(), String> {
        for (r, row) in self.times.iter().enumerate() {
            for (m, &t) in row.iter().enumerate() {
                if !(t.is_finite() && t > 0.0) {
                    return Err(format!("missing/invalid time for ({}, {})", self.routines[r], self.matrices[m]));
                }
            }
        }
        Ok(())
    }

    /// Best time per matrix (over a routine subset, or all with `None`).
    pub fn best_per_matrix(&self, subset: Option<&[usize]>) -> Vec<f64> {
        let idx: Vec<usize> = match subset {
            Some(s) => s.to_vec(),
            None => (0..self.routines.len()).collect(),
        };
        (0..self.matrices.len())
            .map(|m| idx.iter().map(|&r| self.times[r][m]).fold(f64::INFINITY, f64::min))
            .collect()
    }

    /// Index of the best routine per matrix (over a routine subset, or
    /// all with `None`); ties break to the earliest index. Returns an
    /// empty vec for an empty subset.
    pub fn argmin_per_matrix(&self, subset: Option<&[usize]>) -> Vec<usize> {
        let idx: Vec<usize> = match subset {
            Some(s) => s.to_vec(),
            None => (0..self.routines.len()).collect(),
        };
        if idx.is_empty() {
            return Vec::new();
        }
        (0..self.matrices.len())
            .map(|m| {
                idx.iter()
                    .copied()
                    .min_by(|&a, &b| {
                        self.times[a][m]
                            .partial_cmp(&self.times[b][m])
                            .unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .expect("non-empty subset")
            })
            .collect()
    }

    /// Merge another table (same matrices) into this one.
    pub fn extend(&mut self, other: &Measurements) {
        assert_eq!(self.matrices, other.matrices);
        self.routines.extend(other.routines.iter().cloned());
        self.times.extend(other.times.iter().cloned());
    }
}

/// Is routine `r` in the top group of matrix `m` at tolerance `t_pct`,
/// relative to best times `best` (typically over a *larger* collection,
/// cf. Fig 11 where the optimum includes generated variants)?
#[inline]
fn in_top(meas: &Measurements, best: &[f64], r: usize, m: usize, t_pct: f64) -> bool {
    meas.times[r][m] <= (1.0 + t_pct / 100.0) * best[m]
}

/// Weight of routine `r` (number of matrices where it is in the top
/// group) at tolerance `t_pct`.
pub fn weight(meas: &Measurements, best: &[f64], r: usize, t_pct: f64) -> usize {
    (0..meas.matrices.len()).filter(|&m| in_top(meas, best, r, m, t_pct)).count()
}

/// Coverage (max weight over a routine subset) at tolerance `t_pct`,
/// as a fraction of |M| in [0, 1]. `best` is the per-matrix optimum of
/// the *reference* collection.
pub fn coverage(meas: &Measurements, best: &[f64], subset: Option<&[usize]>, t_pct: f64) -> f64 {
    let idx: Vec<usize> = match subset {
        Some(s) => s.to_vec(),
        None => (0..meas.routines.len()).collect(),
    };
    let maxw = idx.iter().map(|&r| weight(meas, best, r, t_pct)).max().unwrap_or(0);
    maxw as f64 / meas.matrices.len() as f64
}

/// The smallest t% at which the subset achieves 100% coverage (paper:
/// "the minimum value of t% that is necessary to find a single
/// best-performing library routine"). Scans in 1% steps to `max_t`.
pub fn min_t_for_full_coverage(
    meas: &Measurements,
    best: &[f64],
    subset: Option<&[usize]>,
    max_t: f64,
) -> Option<f64> {
    let mut t = 0.0;
    while t <= max_t {
        if coverage(meas, best, subset, t) >= 1.0 {
            return Some(t);
        }
        t += 1.0;
    }
    None
}

/// Coverage curve: (t%, coverage) samples for Fig 11.
pub fn coverage_curve(
    meas: &Measurements,
    best: &[f64],
    subset: Option<&[usize]>,
    t_values: &[f64],
) -> Vec<(f64, f64)> {
    t_values.iter().map(|&t| (t, coverage(meas, best, subset, t))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3 routines × 3 matrices. r0 is best on m0/m1, r1 on m2;
    /// r2 always 2x the best.
    fn table() -> Measurements {
        let mut m = Measurements::new(
            vec!["r0".into(), "r1".into(), "r2".into()],
            vec!["m0".into(), "m1".into(), "m2".into()],
        );
        let data = [
            [1.0, 1.0, 2.0], // r0
            [1.5, 1.2, 1.0], // r1
            [2.0, 2.0, 4.0], // r2
        ];
        for (r, row) in data.iter().enumerate() {
            for (c, &t) in row.iter().enumerate() {
                m.set(r, c, t);
            }
        }
        m
    }

    #[test]
    fn best_and_weights() {
        let m = table();
        m.validate().unwrap();
        let best = m.best_per_matrix(None);
        assert_eq!(best, vec![1.0, 1.0, 1.0]);
        assert_eq!(weight(&m, &best, 0, 0.0), 2);
        assert_eq!(weight(&m, &best, 1, 0.0), 1);
        assert_eq!(weight(&m, &best, 2, 0.0), 0);
    }

    #[test]
    fn coverage_monotone_in_t() {
        let m = table();
        let best = m.best_per_matrix(None);
        let c0 = coverage(&m, &best, None, 0.0);
        let c50 = coverage(&m, &best, None, 50.0);
        let c100 = coverage(&m, &best, None, 100.0);
        assert!(c0 <= c50 && c50 <= c100);
        assert!((c0 - 2.0 / 3.0).abs() < 1e-12);
        assert!((c100 - 1.0).abs() < 1e-12); // r0 within 100% everywhere
    }

    #[test]
    fn min_t_full_coverage() {
        let m = table();
        let best = m.best_per_matrix(None);
        // r1 reaches full coverage first: worst cell 1.5 → t = 50%.
        assert_eq!(min_t_for_full_coverage(&m, &best, None, 200.0), Some(50.0));
        // r0 alone needs m2: 2.0 <= (1+t)*1.0 → t = 100%.
        assert_eq!(min_t_for_full_coverage(&m, &best, Some(&[0]), 200.0), Some(100.0));
        // restricted to r2 only: needs 100% on m0/m1 and 300% on m2.
        assert_eq!(min_t_for_full_coverage(&m, &best, Some(&[2]), 200.0), None);
    }

    #[test]
    fn subset_coverage_vs_reference_best() {
        let m = table();
        let best = m.best_per_matrix(None);
        // Only r2 considered, but best still includes everyone:
        let c = coverage(&m, &best, Some(&[2]), 0.0);
        assert_eq!(c, 0.0);
    }

    #[test]
    fn extend_merges() {
        let mut a = table();
        let mut b = Measurements::new(vec!["gen0".into()], a.matrices.clone());
        for c in 0..3 {
            b.set(0, c, 0.5);
        }
        a.extend(&b);
        assert_eq!(a.routines.len(), 4);
        let best = a.best_per_matrix(None);
        assert_eq!(best, vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn curve_shape() {
        let m = table();
        let best = m.best_per_matrix(None);
        let curve = coverage_curve(&m, &best, None, &[0.0, 25.0, 50.0, 100.0]);
        assert_eq!(curve.len(), 4);
        assert!(curve.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn curve_edge_cases() {
        let m = table();
        let best = m.best_per_matrix(None);
        // Empty t-grid → empty curve.
        assert!(coverage_curve(&m, &best, None, &[]).is_empty());
        // t = 0 exactly: only true optima count; r1 covers exactly m2.
        let c = coverage_curve(&m, &best, Some(&[1]), &[0.0]);
        assert!((c[0].1 - 1.0 / 3.0).abs() < 1e-12);
        // Subset of a strictly dominated routine stays at 0 until its
        // worst-case t is reached (r2 is 2x best on m0/m1, 4x on m2).
        let c = coverage_curve(&m, &best, Some(&[2]), &[0.0, 99.0, 100.0, 300.0]);
        assert_eq!(c[0].1, 0.0);
        assert_eq!(c[1].1, 0.0);
        assert!((c[2].1 - 2.0 / 3.0).abs() < 1e-12);
        assert!((c[3].1 - 1.0).abs() < 1e-12);
        // Huge t covers everything for any non-empty subset.
        let c = coverage_curve(&m, &best, Some(&[0]), &[1e6]);
        assert!((c[0].1 - 1.0).abs() < 1e-12);
        // Empty subset: coverage is 0 at every t.
        let c = coverage_curve(&m, &best, Some(&[]), &[0.0, 50.0]);
        assert!(c.iter().all(|&(_, v)| v == 0.0));
    }

    #[test]
    fn argmin_per_matrix_picks_winners() {
        let m = table();
        assert_eq!(m.argmin_per_matrix(None), vec![0, 0, 1]);
        // Restricted to {r1, r2}: r1 wins everywhere.
        assert_eq!(m.argmin_per_matrix(Some(&[1, 2])), vec![1, 1, 1]);
        // Singleton subset maps every matrix to it.
        assert_eq!(m.argmin_per_matrix(Some(&[2])), vec![2, 2, 2]);
        // Empty subset → empty result.
        assert!(m.argmin_per_matrix(Some(&[])).is_empty());
    }

    #[test]
    fn argmin_breaks_ties_to_earliest() {
        let mut m = Measurements::new(
            vec!["a".into(), "b".into()],
            vec!["m0".into()],
        );
        m.set(0, 0, 1.0);
        m.set(1, 0, 1.0);
        assert_eq!(m.argmin_per_matrix(None), vec![0]);
    }
}
