//! The first-class [`Plan`] of the predict→measure planner.
//!
//! # The planner pipeline
//!
//! PR 1 grew the search space to Layout × Traversal × Schedule, but
//! selection was still brute-force: every enumerated variant was
//! measured on every matrix. This module makes the planner's unit of
//! currency explicit so the search can *predict first and measure
//! second*:
//!
//! 1. **Enumerate** — `search::tree::enumerate(kernel, &PlanSpace)`
//!    walks the transformation tree once, crosses the concretizable
//!    chains with the space's schedules, prunes illegal triples
//!    (`Plan::legal_for`), and yields cost-ranked [`Plan`]s.
//! 2. **Predict** — `search::cost::predict` scores every plan on a
//!    matrix's [`MatrixStats`] under the architecture's
//!    [`CostParams`]: an analytic model, no execution.
//! 3. **Measure** — `coordinator::sweep` times only the top-K
//!    predicted plans per matrix (`--shortlist K`; `K = 0` measures
//!    exhaustively and reproduces the paper's tables bit-identically),
//!    and reports predicted-vs-measured rank agreement so the model
//!    stays auditable.
//!
//! A `Plan` carries a *stable*, content-derived id (`csr.row.par4`),
//! its derivation chain, the IR state it concretized from, and the
//! execution triple `exec` (`concretize::Plan`) that `prepare` binds
//! to a matrix. The legality predicate and resource descriptor are
//! methods, not copies: [`Plan::legal_for`] delegates to
//! `concretize::supports`, [`Plan::resources`] to `cost::resources`.

use crate::baselines::Kernel;
use crate::concretize::{self, Plan as ExecPlan, Schedule};
use crate::forelem::ir::ChainState;
use crate::matrix::MatrixStats;
use crate::search::cost::{self, CostParams, FeatureVec, Resources};

/// One automatically instantiated routine + data structure: the unit
/// the planner enumerates, ranks, shortlists and measures.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Stable content-derived id, e.g. `csr.row.serial` or
    /// `ell-cm.plane.par4` — independent of enumeration order.
    pub id: String,
    /// Human-readable derivation, e.g.
    /// "orthogonalize(row) → materialize(dep) → split → nstar(padded)".
    pub derivation: String,
    /// The IR chain state the plan concretized from.
    pub state: ChainState,
    /// The execution tuple: (Layout, Traversal, Schedule, lanes).
    pub exec: ExecPlan,
}

impl Plan {
    /// Build a plan; the id is derived from the execution triple.
    pub fn new(state: ChainState, derivation: String, exec: ExecPlan) -> Self {
        Plan { id: Self::stable_id(&exec), derivation, state, exec }
    }

    /// The stable id of an execution tuple. Scalar plans keep the
    /// pre-lane three-component id (`csr.row.par4`); wide plans append
    /// the vector-width component (`csr.row.par4.v8`), so archives and
    /// quarantine entries from pre-SIMD runs can never alias a wide
    /// plan.
    pub fn stable_id(exec: &ExecPlan) -> String {
        let base =
            format!("{}.{}.{}", exec.layout.slug(), exec.traversal.slug(), exec.schedule.slug());
        if exec.lanes > 1 {
            format!("{base}.v{}", exec.lanes)
        } else {
            base
        }
    }

    /// Short display name: layout + traversal (+ schedule when not
    /// serial, + vector width when wide). A wide plan always carries an
    /// `@` marker — even under `Serial` — so the sweep's
    /// paper-protocol serial subset (`!name.contains('@')`) stays
    /// exactly the scalar serial tree.
    pub fn name(&self) -> String {
        let mut name = if self.exec.schedule.is_serial() {
            format!("{:?}/{:?}", self.exec.layout, self.exec.traversal)
        } else {
            format!(
                "{:?}/{:?}@{}",
                self.exec.layout,
                self.exec.traversal,
                self.exec.schedule.label()
            )
        };
        if self.exec.lanes > 1 {
            name.push_str(&format!("@v{}", self.exec.lanes));
        }
        name
    }

    /// Legality predicate: can this plan's generated loop nest execute
    /// `kernel` (dependences respected, schedule legal for the layout)?
    pub fn legal_for(&self, kernel: Kernel) -> bool {
        concretize::supports(&self.exec, kernel)
    }

    /// Resource descriptor on a concrete matrix: bytes touched, gather
    /// working set per cache level, flop count, parallel grain.
    pub fn resources(&self, kernel: Kernel, dense_k: usize, stats: &MatrixStats) -> Resources {
        cost::resources(kernel, dense_k, &self.exec, stats)
    }

    /// Predicted execution time (seconds) on a matrix, stage 1 of the
    /// pipeline.
    pub fn predict(
        &self,
        kernel: Kernel,
        dense_k: usize,
        stats: &MatrixStats,
        params: &CostParams,
    ) -> f64 {
        cost::predict(kernel, dense_k, &self.exec, stats, params)
    }

    /// The fittable feature vector behind [`predict`](Self::predict):
    /// `predict == features.dot(&params.weights)` (clamped positive).
    /// This is what the sweep archives per measured cell for
    /// `search::calibrate`.
    pub fn features(
        &self,
        kernel: Kernel,
        dense_k: usize,
        stats: &MatrixStats,
        params: &CostParams,
    ) -> FeatureVec {
        cost::features(kernel, dense_k, &self.exec, stats, params)
    }
}

/// The space `enumerate` explores: which schedules to cross with the
/// serial tree, the architecture parameters that score plans, and the
/// reference statistics used for the returned ranking.
#[derive(Clone, Debug)]
pub struct PlanSpace {
    /// Schedules crossed with every serial (layout, traversal) pair.
    pub schedules: Vec<Schedule>,
    /// Vector widths crossed with every scheduled plan (`1` = scalar;
    /// `concretize::lane_legal` prunes illegal format/width pairs).
    pub lanes: Vec<usize>,
    /// Architecture parameters of the cost model.
    pub params: CostParams,
    /// Dense-operand column count assumed when ranking SpMM plans.
    pub dense_k: usize,
    /// Statistics the returned plan list is ranked against; `None`
    /// ranks against [`MatrixStats::nominal`]. Per-matrix shortlists
    /// re-rank with real statistics regardless.
    pub rank_stats: Option<MatrixStats>,
}

impl PlanSpace {
    /// Only `Serial` — the paper's measurement protocol.
    pub fn serial_only() -> Self {
        PlanSpace {
            schedules: vec![Schedule::Serial],
            lanes: vec![1],
            params: CostParams::host_small(),
            dense_k: 100,
            rank_stats: None,
        }
    }

    /// Serial + parallel + tiled + both, for a host with `threads`
    /// workers and an L2 that holds `x_block` doubles of `x` band.
    pub fn host(threads: usize, x_block: usize) -> Self {
        PlanSpace {
            schedules: vec![
                Schedule::Serial,
                Schedule::Parallel { threads },
                Schedule::Tiled { x_block },
                Schedule::ParallelTiled { threads, x_block },
            ],
            lanes: vec![1, 4, 8],
            params: CostParams::host_large(threads),
            dense_k: 100,
            rank_stats: None,
        }
    }

    /// Rank the enumeration against concrete matrix statistics.
    pub fn with_rank_stats(mut self, stats: MatrixStats) -> Self {
        self.rank_stats = Some(stats);
        self
    }

    /// The statistics enumeration ranks against.
    pub fn ranking_stats(&self) -> MatrixStats {
        self.rank_stats.unwrap_or_else(MatrixStats::nominal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::concretize::{Layout, Traversal};

    #[test]
    fn stable_ids_are_content_derived() {
        let a = ExecPlan::serial(Layout::Csr, Traversal::RowWise);
        assert_eq!(Plan::stable_id(&a), "csr.row.serial");
        let b = a.with_schedule(Schedule::Parallel { threads: 4 });
        assert_eq!(Plan::stable_id(&b), "csr.row.par4");
        let c = a.with_schedule(Schedule::ParallelTiled { threads: 2, x_block: 4096 });
        assert_eq!(Plan::stable_id(&c), "csr.row.par2-tile4096");
        let d = ExecPlan::serial(Layout::Sell { s: 32 }, Traversal::SlicePlane);
        assert_eq!(Plan::stable_id(&d), "sell32.slice.serial");
    }

    #[test]
    fn wide_plans_append_the_vector_width_component() {
        let a = ExecPlan::serial(Layout::Csr, Traversal::RowWise);
        assert_eq!(Plan::stable_id(&a.with_lanes(8)), "csr.row.serial.v8");
        let b = a.with_schedule(Schedule::Parallel { threads: 4 }).with_lanes(4);
        assert_eq!(Plan::stable_id(&b), "csr.row.par4.v4");
        // lanes = 1 is the scalar id — bit-for-bit the pre-SIMD form.
        assert_eq!(Plan::stable_id(&a.with_lanes(1)), "csr.row.serial");
    }

    #[test]
    fn wide_plan_names_carry_the_marker_even_when_serial() {
        let state = ChainState::initial(Kernel::Spmv);
        let wide = Plan::new(
            state.clone(),
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise).with_lanes(8),
        );
        assert!(wide.name().contains("@v8"), "{}", wide.name());
        let wide_par = Plan::new(
            state,
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::Parallel { threads: 2 })
                .with_lanes(4),
        );
        assert!(wide_par.name().contains("@par(2)") && wide_par.name().contains("@v4"));
    }

    #[test]
    fn plan_name_marks_non_serial_schedules() {
        let state = ChainState::initial(Kernel::Spmv);
        let serial = Plan::new(
            state.clone(),
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise),
        );
        assert!(!serial.name().contains('@'));
        let par = Plan::new(
            state,
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::Parallel { threads: 3 }),
        );
        assert!(par.name().contains("@par(3)"));
    }

    #[test]
    fn legality_delegates_to_concretize() {
        let state = ChainState::initial(Kernel::Spmv);
        let par = Plan::new(
            state,
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::Parallel { threads: 4 }),
        );
        assert!(par.legal_for(Kernel::Spmv));
        assert!(par.legal_for(Kernel::Spmm));
        assert!(!par.legal_for(Kernel::Trsv));
    }

    #[test]
    fn resources_and_prediction_are_exposed() {
        let state = ChainState::initial(Kernel::Spmv);
        let p = Plan::new(
            state,
            "x".into(),
            ExecPlan::serial(Layout::Csr, Traversal::RowWise),
        );
        let stats = MatrixStats::nominal();
        let r = p.resources(Kernel::Spmv, 1, &stats);
        assert!(r.streamed_bytes > 0.0 && r.flops > 0.0);
        assert!(r.parallel_grain >= 1);
        let params = CostParams::host_small();
        let t = p.predict(Kernel::Spmv, 1, &stats, &params);
        assert!(t.is_finite() && t > 0.0);
        // The fittable form is exposed and consistent with predict.
        let f = p.features(Kernel::Spmv, 1, &stats, &params);
        assert_eq!(f.dot(&params.weights).max(1e-12), t);
    }

    #[test]
    fn plan_space_defaults() {
        let s = PlanSpace::serial_only();
        assert_eq!(s.schedules, vec![Schedule::Serial]);
        assert_eq!(s.lanes, vec![1], "the paper protocol stays scalar");
        assert!(s.rank_stats.is_none());
        let h = PlanSpace::host(4, 4096);
        assert_eq!(h.schedules.len(), 4);
        assert_eq!(h.lanes, vec![1, 4, 8]);
        assert_eq!(h.params.threads, 4);
        let ranked = h.with_rank_stats(MatrixStats::synthetic(10, 10, 2.0, 0.0, 2, 5));
        assert_eq!(ranked.ranking_stats().nrows, 10);
    }
}
