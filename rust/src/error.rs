//! The typed error taxonomy of the serving path.
//!
//! The paper's contract is that the *compiler* owns the data structure,
//! so a bad plan, profile or measurement at runtime is the system's
//! problem to recover from — not a reason to crash the caller. Every
//! fallible seam of the compile-and-serve pipeline surfaces one of the
//! [`ForelemError`] variants below; everything that can be *degraded
//! around* instead (corrupt profile, panicking candidate, hung
//! measurement) never reaches the caller at all — it lands a rung down
//! the ladder recorded as [`crate::engine::Health`].
//!
//! The taxonomy is deliberately small: four variants, one per failure
//! *class*, each carrying a human-readable reason rather than a deep
//! structured payload — embedding hosts branch on the class and log
//! the string.

use std::fmt;

/// Why a `forelem` operation failed. The only variant
/// `Engine::compile` itself can return is [`InvalidMatrix`]
/// (everything else degrades — see the ladder in
/// [`crate::engine::Health`]); the rest surface from ingestion
/// (`matrix::mmio`), artifact IO and the pinned-plan API.
///
/// [`InvalidMatrix`]: ForelemError::InvalidMatrix
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ForelemError {
    /// The tuple reservoir violates its invariants: out-of-bounds
    /// indices, duplicate `(row, col)` pairs, NaN/Inf values, zero or
    /// overflowing dimensions. Detected at ingestion by
    /// [`crate::matrix::TriMat::validate`].
    InvalidMatrix(String),
    /// An on-disk artifact (tuning profile, sample archive, manifest)
    /// is unreadable or fails its integrity check.
    CorruptArtifact {
        /// Path of the offending artifact (display form).
        path: String,
        reason: String,
    },
    /// An autotune candidate measurement panicked, timed out under the
    /// watchdog, or could not produce a finite time. The engine
    /// quarantines the candidate and falls through; this variant
    /// surfaces only from APIs that expose single measurements.
    MeasurementFailure {
        /// Stable plan id of the candidate (e.g. `csr.row.serial`).
        plan_id: String,
        reason: String,
    },
    /// A plan id or execution triple that the requested pipeline
    /// cannot serve (unknown pinned id, kernel/plan mismatch).
    UnsupportedPlan {
        plan_id: String,
        reason: String,
    },
}

impl ForelemError {
    /// Short stable class label (`invalid-matrix`, `corrupt-artifact`,
    /// `measurement-failure`, `unsupported-plan`) — for metrics keys
    /// and log grepping.
    pub fn class(&self) -> &'static str {
        match self {
            ForelemError::InvalidMatrix(_) => "invalid-matrix",
            ForelemError::CorruptArtifact { .. } => "corrupt-artifact",
            ForelemError::MeasurementFailure { .. } => "measurement-failure",
            ForelemError::UnsupportedPlan { .. } => "unsupported-plan",
        }
    }
}

impl fmt::Display for ForelemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ForelemError::InvalidMatrix(reason) => write!(f, "invalid matrix: {reason}"),
            ForelemError::CorruptArtifact { path, reason } => {
                write!(f, "corrupt artifact {path}: {reason}")
            }
            ForelemError::MeasurementFailure { plan_id, reason } => {
                write!(f, "measurement of plan {plan_id} failed: {reason}")
            }
            ForelemError::UnsupportedPlan { plan_id, reason } => {
                write!(f, "unsupported plan {plan_id}: {reason}")
            }
        }
    }
}

impl std::error::Error for ForelemError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_class_and_reason() {
        let cases: Vec<(ForelemError, &str, &str)> = vec![
            (ForelemError::InvalidMatrix("nan at (1, 2)".into()), "invalid-matrix", "nan"),
            (
                ForelemError::CorruptArtifact { path: "t/p.profile".into(), reason: "checksum".into() },
                "corrupt-artifact",
                "t/p.profile",
            ),
            (
                ForelemError::MeasurementFailure { plan_id: "csr.row.serial".into(), reason: "hung".into() },
                "measurement-failure",
                "csr.row.serial",
            ),
            (
                ForelemError::UnsupportedPlan { plan_id: "no.such".into(), reason: "not in pool".into() },
                "unsupported-plan",
                "no.such",
            ),
        ];
        for (e, class, frag) in cases {
            assert_eq!(e.class(), class);
            let text = e.to_string();
            assert!(text.contains(frag), "{text} missing {frag}");
            // The taxonomy is a real std error.
            let _: &dyn std::error::Error = &e;
        }
    }
}
