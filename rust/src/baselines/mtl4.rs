//! MTL4-style baselines. MTL4 (Gottschling et al., ICS 2007) is built on
//! *representation-transparent generic programming*: algorithms are
//! written against cursors and property maps, not concrete storage. The
//! abstraction compiles away only partially in practice; we mirror the
//! idiom with a cursor trait driven through dynamic dispatch per row/
//! column segment — the moderate abstraction-overhead class the paper's
//! MTL4 numbers exhibit.

use crate::matrix::TriMat;
use crate::storage::{Csc, Csr};

/// Generic nonzero cursor: yields (minor_index, value) along one major
/// slice (a row of CRS or a column of CCS).
pub trait NnzCursor {
    fn next_nz(&mut self) -> Option<(usize, f64)>;
}

struct SliceCursor<'a> {
    idx: &'a [u32],
    val: &'a [f64],
    pos: usize,
}

impl<'a> NnzCursor for SliceCursor<'a> {
    #[inline]
    fn next_nz(&mut self) -> Option<(usize, f64)> {
        if self.pos < self.idx.len() {
            let p = self.pos;
            self.pos += 1;
            Some((self.idx[p] as usize, self.val[p]))
        } else {
            None
        }
    }
}

pub struct Mtl4Crs {
    pub a: Csr,
}

pub struct Mtl4Ccs {
    pub a: Csc,
}

impl Mtl4Crs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csr::from_tuples(m) }
    }

    fn row_cursor(&self, i: usize) -> Box<dyn NnzCursor + '_> {
        let (s, e) = (self.a.row_ptr[i] as usize, self.a.row_ptr[i + 1] as usize);
        Box::new(SliceCursor { idx: &self.a.cols[s..e], val: &self.a.vals[s..e], pos: 0 })
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        for i in 0..self.a.nrows {
            let mut cur = self.row_cursor(i);
            let mut sum = 0.0;
            while let Some((c, v)) = cur.next_nz() {
                sum += v * x[c];
            }
            y[i] = sum;
        }
    }

    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        for i in 0..self.a.nrows {
            let crow = &mut c[i * k..i * k + k];
            crow.fill(0.0);
            let mut cur = self.row_cursor(i);
            while let Some((col, v)) = cur.next_nz() {
                let brow = &b[col * k..col * k + k];
                for j in 0..k {
                    crow[j] += v * brow[j];
                }
            }
        }
    }

    /// Unit-lower forward substitution (strictly-lower storage).
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        x.copy_from_slice(b);
        for i in 0..self.a.nrows {
            let mut cur = self.row_cursor(i);
            let mut sum = 0.0;
            while let Some((c, v)) = cur.next_nz() {
                sum += v * x[c];
            }
            x[i] -= sum;
        }
    }
}

impl Mtl4Ccs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csc::from_tuples(m) }
    }

    fn col_cursor(&self, j: usize) -> Box<dyn NnzCursor + '_> {
        let (s, e) = (self.a.col_ptr[j] as usize, self.a.col_ptr[j + 1] as usize);
        Box::new(SliceCursor { idx: &self.a.rows[s..e], val: &self.a.vals[s..e], pos: 0 })
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        for j in 0..self.a.ncols {
            let xj = x[j];
            let mut cur = self.col_cursor(j);
            while let Some((r, v)) = cur.next_nz() {
                y[r] += v * xj;
            }
        }
    }

    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        c.fill(0.0);
        for j in 0..self.a.ncols {
            let brow = &b[j * k..j * k + k];
            let mut cur = self.col_cursor(j);
            while let Some((r, v)) = cur.next_nz() {
                let crow = &mut c[r * k..r * k + k];
                for jj in 0..k {
                    crow[jj] += v * brow[jj];
                }
            }
        }
    }

    /// Unit-lower forward substitution, scatter form.
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        x.copy_from_slice(b);
        for j in 0..self.a.ncols {
            let xj = x[j];
            let mut cur = self.col_cursor(j);
            while let Some((r, v)) = cur.next_nz() {
                x[r] -= v * xj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    #[test]
    fn mtl4_spmv_matches() {
        let m = gen::banded(35, 5, 0.6, 52);
        let x: Vec<f64> = (0..35).map(|i| (i as f64 * 0.3).cos()).collect();
        let want = m.spmv_ref(&x);
        let mut y = vec![0.0; 35];
        Mtl4Crs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
        Mtl4Ccs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
    }

    #[test]
    fn mtl4_spmm_matches() {
        let m = gen::uniform_random(20, 25, 120, 53);
        let k = 4;
        let b: Vec<f64> = (0..25 * k).map(|i| i as f64 * 0.02).collect();
        let want = m.spmm_ref(&b, k);
        let mut c = vec![0.0; 20 * k];
        Mtl4Crs::new(&m).spmm(&b, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
        Mtl4Ccs::new(&m).spmm(&b, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
    }

    #[test]
    fn mtl4_trsv_matches() {
        let m = gen::uniform_random(30, 30, 180, 54);
        let l = m.strictly_lower();
        let b: Vec<f64> = (0..30).map(|i| (i as f64) * 0.1 - 1.0).collect();
        let want = l.trsv_unit_lower_ref(&b);
        let mut x = vec![0.0; 30];
        Mtl4Crs::new(&l).trsv(&b, &mut x);
        assert_close(&x, &want, 1e-9).unwrap();
        Mtl4Ccs::new(&l).trsv(&b, &mut x);
        assert_close(&x, &want, 1e-9).unwrap();
    }
}
