//! Library baselines: the 7 routine/data-structure combinations the
//! paper benchmarks against (§6.4.1) — Blaze CRS/CCS, MTL4 CRS/CCS,
//! SparseLib++ COO/CRS/CCS — re-implemented in each library's idiom
//! (see DESIGN.md §5 Substitutions). SpMM exists only for Blaze and
//! MTL4; TrSv only for MTL4 and SparseLib++ — exactly the support
//! matrix of the paper's tables.

pub mod blaze;
pub mod mtl4;
pub mod sparselib;

use crate::matrix::TriMat;

/// Which computational kernel (paper §6.4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kernel {
    Spmv,
    Spmm,
    Trsv,
}

impl Kernel {
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Spmv => "SPMV",
            Kernel::Spmm => "SPMM",
            Kernel::Trsv => "TrSv",
        }
    }
}

/// Identity of a library routine (a column of Tables 1–3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LibRoutine {
    BlazeCrs,
    BlazeCcs,
    Mtl4Crs,
    Mtl4Ccs,
    SlppCoo,
    SlppCrs,
    SlppCcs,
}

pub const ALL_ROUTINES: [LibRoutine; 7] = [
    LibRoutine::BlazeCrs,
    LibRoutine::BlazeCcs,
    LibRoutine::Mtl4Crs,
    LibRoutine::Mtl4Ccs,
    LibRoutine::SlppCoo,
    LibRoutine::SlppCrs,
    LibRoutine::SlppCcs,
];

impl LibRoutine {
    pub fn library(&self) -> &'static str {
        match self {
            LibRoutine::BlazeCrs | LibRoutine::BlazeCcs => "Blaze",
            LibRoutine::Mtl4Crs | LibRoutine::Mtl4Ccs => "MTL4",
            _ => "SL++",
        }
    }

    pub fn format(&self) -> &'static str {
        match self {
            LibRoutine::BlazeCrs | LibRoutine::Mtl4Crs | LibRoutine::SlppCrs => "CRS",
            LibRoutine::BlazeCcs | LibRoutine::Mtl4Ccs | LibRoutine::SlppCcs => "CCS",
            LibRoutine::SlppCoo => "COO",
        }
    }

    pub fn label(&self) -> String {
        format!("{} {}", self.library(), self.format())
    }

    /// The paper's support matrix: SpMM only in Blaze+MTL4 ("SparseLib++
    /// did not contain API for this computation"); TrSv only in
    /// MTL4+SL++.
    pub fn supports(&self, kernel: Kernel) -> bool {
        match kernel {
            Kernel::Spmv => true,
            Kernel::Spmm => matches!(
                self,
                LibRoutine::BlazeCrs | LibRoutine::BlazeCcs | LibRoutine::Mtl4Crs | LibRoutine::Mtl4Ccs
            ),
            Kernel::Trsv => matches!(
                self,
                LibRoutine::Mtl4Crs | LibRoutine::Mtl4Ccs | LibRoutine::SlppCrs | LibRoutine::SlppCcs
            ),
        }
    }

    /// Build the routine's data structure for matrix `m`.
    pub fn prepare(&self, m: &TriMat) -> LibInstance {
        match self {
            LibRoutine::BlazeCrs => LibInstance::BlazeCrs(blaze::BlazeCrs::new(m)),
            LibRoutine::BlazeCcs => LibInstance::BlazeCcs(blaze::BlazeCcs::new(m)),
            LibRoutine::Mtl4Crs => LibInstance::Mtl4Crs(mtl4::Mtl4Crs::new(m)),
            LibRoutine::Mtl4Ccs => LibInstance::Mtl4Ccs(mtl4::Mtl4Ccs::new(m)),
            LibRoutine::SlppCoo => LibInstance::SlppCoo(sparselib::SlppCoo::new(m)),
            LibRoutine::SlppCrs => LibInstance::SlppCrs(sparselib::SlppCrs::new(m)),
            LibRoutine::SlppCcs => LibInstance::SlppCcs(sparselib::SlppCcs::new(m)),
        }
    }
}

/// A prepared library routine bound to a concrete matrix.
pub enum LibInstance {
    BlazeCrs(blaze::BlazeCrs),
    BlazeCcs(blaze::BlazeCcs),
    Mtl4Crs(mtl4::Mtl4Crs),
    Mtl4Ccs(mtl4::Mtl4Ccs),
    SlppCoo(sparselib::SlppCoo),
    SlppCrs(sparselib::SlppCrs),
    SlppCcs(sparselib::SlppCcs),
}

impl LibInstance {
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match self {
            LibInstance::BlazeCrs(r) => r.spmv(x, y),
            LibInstance::BlazeCcs(r) => r.spmv(x, y),
            LibInstance::Mtl4Crs(r) => r.spmv(x, y),
            LibInstance::Mtl4Ccs(r) => r.spmv(x, y),
            LibInstance::SlppCoo(r) => r.spmv(x, y),
            LibInstance::SlppCrs(r) => r.spmv(x, y),
            LibInstance::SlppCcs(r) => r.spmv(x, y),
        }
    }

    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        match self {
            LibInstance::BlazeCrs(r) => r.spmm(b, k, c),
            LibInstance::BlazeCcs(r) => r.spmm(b, k, c),
            LibInstance::Mtl4Crs(r) => r.spmm(b, k, c),
            LibInstance::Mtl4Ccs(r) => r.spmm(b, k, c),
            _ => panic!("SpMM not supported by this library routine (as in the paper)"),
        }
    }

    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        match self {
            LibInstance::Mtl4Crs(r) => r.trsv(b, x),
            LibInstance::Mtl4Ccs(r) => r.trsv(b, x),
            LibInstance::SlppCrs(r) => r.trsv(b, x),
            LibInstance::SlppCcs(r) => r.trsv(b, x),
            _ => panic!("TrSv not supported by this library routine (as in the paper)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    #[test]
    fn support_matrix_matches_paper() {
        let spmm: Vec<_> = ALL_ROUTINES.iter().filter(|r| r.supports(Kernel::Spmm)).collect();
        assert_eq!(spmm.len(), 4);
        let trsv: Vec<_> = ALL_ROUTINES.iter().filter(|r| r.supports(Kernel::Trsv)).collect();
        assert_eq!(trsv.len(), 4);
        assert!(ALL_ROUTINES.iter().all(|r| r.supports(Kernel::Spmv)));
    }

    #[test]
    fn all_routines_spmv_agree() {
        let m = gen::powerlaw(40, 2.0, 20, 57);
        let x: Vec<f64> = (0..40).map(|i| 0.3 * i as f64 - 4.0).collect();
        let want = m.spmv_ref(&x);
        for r in ALL_ROUTINES {
            let inst = r.prepare(&m);
            let mut y = vec![0.0; 40];
            inst.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{}: {e}", r.label()));
        }
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<String> = ALL_ROUTINES.iter().map(|r| r.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 7);
    }
}
