//! SparseLib++-style baselines. SparseLib++ 1.7 (Dongarra et al., 1994)
//! is classic 90s C++: concrete `Coord_Mat_double`, `CompRow_Mat_double`
//! and `CompCol_Mat_double` classes whose kernels are plain indexed loops
//! with `operator()`-style element access. We mirror that idiom with
//! straightforward index arithmetic on `Vec`s (bounds-checked, no
//! iterator fusion) — the "plain C loops" overhead class.

// The 90s-C++ loop idiom below is deliberate (it *is* the baseline being
// modeled); silence the style lints that would "fix" it away.
#![allow(clippy::assign_op_pattern, clippy::needless_range_loop, clippy::manual_memcpy)]

use crate::matrix::TriMat;
use crate::storage::{CooSoa, CooOrder, Csc, Csr};

/// `Coord_Mat_double`: coordinate storage in file order.
pub struct SlppCoo {
    pub a: CooSoa,
}

/// `CompRow_Mat_double`.
pub struct SlppCrs {
    pub a: Csr,
}

/// `CompCol_Mat_double`.
pub struct SlppCcs {
    pub a: Csc,
}

impl SlppCoo {
    pub fn new(m: &TriMat) -> Self {
        // SparseLib++ keeps coordinate entries in the order they arrived.
        Self { a: CooSoa::from_tuples(m, CooOrder::Unsorted) }
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        for i in 0..y.len() {
            y[i] = 0.0;
        }
        let nnz = self.a.vals.len();
        for t in 0..nnz {
            let i = self.a.rows[t] as usize;
            let j = self.a.cols[t] as usize;
            y[i] = y[i] + self.a.vals[t] * x[j];
        }
    }
}

impl SlppCrs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csr::from_tuples(m) }
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let a = &self.a;
        for i in 0..a.nrows {
            let mut t = 0.0;
            let start = a.row_ptr[i] as usize;
            let stop = a.row_ptr[i + 1] as usize;
            for p in start..stop {
                t = t + a.vals[p] * x[a.cols[p] as usize];
            }
            y[i] = t;
        }
    }

    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        let a = &self.a;
        for i in 0..a.nrows {
            x[i] = b[i];
        }
        for i in 0..a.nrows {
            let mut t = 0.0;
            let start = a.row_ptr[i] as usize;
            let stop = a.row_ptr[i + 1] as usize;
            for p in start..stop {
                t = t + a.vals[p] * x[a.cols[p] as usize];
            }
            x[i] = x[i] - t;
        }
    }
}

impl SlppCcs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csc::from_tuples(m) }
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let a = &self.a;
        for i in 0..y.len() {
            y[i] = 0.0;
        }
        for j in 0..a.ncols {
            let start = a.col_ptr[j] as usize;
            let stop = a.col_ptr[j + 1] as usize;
            for p in start..stop {
                let i = a.rows[p] as usize;
                y[i] = y[i] + a.vals[p] * x[j];
            }
        }
    }

    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        let a = &self.a;
        for i in 0..a.nrows {
            x[i] = b[i];
        }
        for j in 0..a.ncols {
            let start = a.col_ptr[j] as usize;
            let stop = a.col_ptr[j + 1] as usize;
            for p in start..stop {
                let i = a.rows[p] as usize;
                x[i] = x[i] - a.vals[p] * x[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    #[test]
    fn slpp_spmv_all_three_match() {
        let m = gen::circuit(40, 2, 10, 55);
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.21).sin() + 0.4).collect();
        let want = m.spmv_ref(&x);
        let mut y = vec![0.0; 40];
        SlppCoo::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
        SlppCrs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
        SlppCcs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
    }

    #[test]
    fn slpp_trsv_matches() {
        let m = gen::uniform_random(25, 25, 140, 56);
        let l = m.strictly_lower();
        let b: Vec<f64> = (0..25).map(|i| 1.0 - (i as f64) * 0.05).collect();
        let want = l.trsv_unit_lower_ref(&b);
        let mut x = vec![0.0; 25];
        SlppCrs::new(&l).trsv(&b, &mut x);
        assert_close(&x, &want, 1e-9).unwrap();
        SlppCcs::new(&l).trsv(&b, &mut x);
        assert_close(&x, &want, 1e-9).unwrap();
    }
}
