//! Blaze-style baselines. Blaze is a "smart expression template" library
//! (Iglberger et al., HPCS 2012): assignments like `y = A * x` are
//! evaluated by fused, heavily-inlined kernels selected at compile time,
//! with the matrix in either row-major (CRS) or column-major (CCS)
//! compressed storage. We mirror that idiom with iterator-fused Rust:
//! tight zipped iterators, no intermediate allocations.

use crate::matrix::TriMat;
use crate::storage::{Csc, Csr};

pub struct BlazeCrs {
    pub a: Csr,
}

pub struct BlazeCcs {
    pub a: Csc,
}

impl BlazeCrs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csr::from_tuples(m) }
    }

    /// `y = A * x` — expression-template style: per-row fused
    /// map/sum over zipped (col, val) iterators.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let a = &self.a;
        for (i, yi) in y.iter_mut().enumerate() {
            let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
            *yi = a.cols[s..e]
                .iter()
                .zip(&a.vals[s..e])
                .map(|(&c, &v)| v * x[c as usize])
                .sum();
        }
    }

    /// `C = A * B` with dense row-major B (ncols × k).
    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        let a = &self.a;
        for i in 0..a.nrows {
            let crow = &mut c[i * k..i * k + k];
            crow.fill(0.0);
            let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
            for (&col, &v) in a.cols[s..e].iter().zip(&a.vals[s..e]) {
                let brow = &b[col as usize * k..col as usize * k + k];
                crow.iter_mut().zip(brow).for_each(|(ci, &bi)| *ci += v * bi);
            }
        }
    }
}

impl BlazeCcs {
    pub fn new(m: &TriMat) -> Self {
        Self { a: Csc::from_tuples(m) }
    }

    /// Column-major SpMV: expression evaluation visits columns; Blaze
    /// evaluates `y = A * x` for a column-major A with a scatter kernel.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let a = &self.a;
        y.fill(0.0);
        for j in 0..a.ncols {
            let (s, e) = (a.col_ptr[j] as usize, a.col_ptr[j + 1] as usize);
            let xj = x[j];
            a.rows[s..e]
                .iter()
                .zip(&a.vals[s..e])
                .for_each(|(&r, &v)| y[r as usize] += v * xj);
        }
    }

    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        let a = &self.a;
        c.fill(0.0);
        for j in 0..a.ncols {
            let (s, e) = (a.col_ptr[j] as usize, a.col_ptr[j + 1] as usize);
            let brow = &b[j * k..j * k + k];
            for (&r, &v) in a.rows[s..e].iter().zip(&a.vals[s..e]) {
                let crow = &mut c[r as usize * k..r as usize * k + k];
                crow.iter_mut().zip(brow).for_each(|(ci, &bi)| *ci += v * bi);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    #[test]
    fn blaze_spmv_matches_oracle() {
        let m = gen::uniform_random(30, 40, 250, 50);
        let x: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let want = m.spmv_ref(&x);
        let mut y = vec![0.0; 30];
        BlazeCrs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
        BlazeCcs::new(&m).spmv(&x, &mut y);
        assert_close(&y, &want, 1e-10).unwrap();
    }

    #[test]
    fn blaze_spmm_matches_oracle() {
        let m = gen::powerlaw(25, 2.0, 12, 51);
        let k = 5;
        let b: Vec<f64> = (0..m.ncols * k).map(|i| i as f64 * 0.01 - 0.5).collect();
        let want = m.spmm_ref(&b, k);
        let mut c = vec![0.0; m.nrows * k];
        BlazeCrs::new(&m).spmm(&b, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
        BlazeCcs::new(&m).spmm(&b, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
    }
}
