//! Automatic data partitioning and distribution (paper §6.2.4): the
//! loop-blocking transformation, applied with different partitionings of
//! the iteration domain, generates *data distributions* for parallel
//! sparse computation. Three partitioners are generated here:
//!
//! * `rows_even` — ℕ_m split into equal index ranges (plain blocking,
//!   Fig 4 left: "partitioning is done regardless of the tuples").
//! * `rows_balanced` — ℕ* blocked after materialization (Fig 4 right):
//!   split points chosen on the materialized nonzeros so parts carry
//!   nearly equal nnz.
//! * `grid_2d` — both dimensions blocked with irregular split points
//!   balancing nonzeros, the Vastenhouw–Bisseling-style 2-D distribution
//!   the paper cites.
//!
//! The executor runs one worker per part on the `util::pool` thread pool
//! (the paper's "distributed and parallel data structures" substrate).

pub mod partition;

pub use partition::{grid_2d, rows_balanced, rows_even, Partition};

use crate::matrix::TriMat;
use crate::storage::Csr;
use crate::util::pool::parallel_map;

/// A parallel SpMV over a row partition: each part owns a CSR of its
/// rows; y is computed part-locally (no write conflicts).
pub struct PartitionedSpmv {
    /// (start_row, csr over rows [start, end)) per part.
    parts: Vec<(usize, Csr)>,
    pub nrows: usize,
    pub ncols: usize,
}

impl PartitionedSpmv {
    pub fn new(m: &TriMat, parts: &Partition) -> Self {
        assert_eq!(parts.kind, partition::Kind::Rows);
        let built = parts
            .row_ranges
            .iter()
            .map(|&(lo, hi)| {
                let mut sub = TriMat::new(hi - lo, m.ncols);
                for e in &m.entries {
                    let r = e.row as usize;
                    if (lo..hi).contains(&r) {
                        sub.push(r - lo, e.col as usize, e.val);
                    }
                }
                (lo, Csr::from_tuples(&sub))
            })
            .collect();
        PartitionedSpmv { parts: built, nrows: m.nrows, ncols: m.ncols }
    }

    /// Parallel `y = A x`, one worker per part.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let results = parallel_map(self.parts.len(), self.parts.len().max(1), |p| {
            let (lo, csr) = &self.parts[p];
            let mut local = vec![0.0; csr.nrows];
            crate::kernels::spmv::csr(csr, x, &mut local);
            (*lo, local)
        });
        for (lo, local) in results {
            y[lo..lo + local.len()].copy_from_slice(&local);
        }
    }

    /// nnz per part — the balance metric the partitioners optimize.
    pub fn nnz_per_part(&self) -> Vec<usize> {
        self.parts.iter().map(|(_, c)| c.nnz()).collect()
    }
}

/// Load imbalance: max part nnz / mean part nnz (1.0 = perfect).
pub fn imbalance(nnz_per_part: &[usize]) -> f64 {
    if nnz_per_part.is_empty() {
        return 1.0;
    }
    let max = *nnz_per_part.iter().max().unwrap() as f64;
    let mean = nnz_per_part.iter().sum::<usize>() as f64 / nnz_per_part.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    #[test]
    fn partitioned_spmv_matches_oracle() {
        let m = gen::powerlaw(300, 1.9, 80, 300);
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.03).sin()).collect();
        let want = m.spmv_ref(&x);
        for nparts in [1, 2, 4, 8] {
            for part in [rows_even(&m, nparts), rows_balanced(&m, nparts)] {
                let p = PartitionedSpmv::new(&m, &part);
                let mut y = vec![0.0; 300];
                p.spmv(&x, &mut y);
                assert_close(&y, &want, 1e-10)
                    .unwrap_or_else(|e| panic!("{nparts} parts: {e}"));
            }
        }
    }

    #[test]
    fn balanced_beats_even_on_skew() {
        // Power-law: early rows are hubs; even row split is imbalanced.
        let m = gen::powerlaw(600, 1.7, 300, 301);
        let even = PartitionedSpmv::new(&m, &rows_even(&m, 8));
        let bal = PartitionedSpmv::new(&m, &rows_balanced(&m, 8));
        let ie = imbalance(&even.nnz_per_part());
        let ib = imbalance(&bal.nnz_per_part());
        assert!(ib <= ie + 1e-9, "balanced {ib} vs even {ie}");
        assert!(ib < 1.5, "balanced partition too uneven: {ib}");
    }

    #[test]
    fn imbalance_metric() {
        assert!((imbalance(&[10, 10, 10]) - 1.0).abs() < 1e-12);
        assert!((imbalance(&[30, 0, 0]) - 3.0).abs() < 1e-12);
        assert_eq!(imbalance(&[]), 1.0);
    }
}
