//! Partitioners: different ways to split the blocked iteration domain,
//! each "simply a different method for the partitioning of ℕ_m"
//! (paper §6.2.4).

use crate::matrix::TriMat;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Rows,
    Grid2d,
}

/// A partition of the matrix iteration domain.
#[derive(Clone, Debug)]
pub struct Partition {
    pub kind: Kind,
    /// Row ranges `[lo, hi)` (Kind::Rows), or empty.
    pub row_ranges: Vec<(usize, usize)>,
    /// Row and column split points (Kind::Grid2d): the 2-D blocks are
    /// the cross product of consecutive split intervals.
    pub row_splits: Vec<usize>,
    pub col_splits: Vec<usize>,
}

/// Equal index ranges — blocking *before* materialization (Fig 4 left):
/// oblivious to where the nonzeros actually are.
pub fn rows_even(m: &TriMat, nparts: usize) -> Partition {
    let nparts = nparts.max(1).min(m.nrows.max(1));
    let chunk = m.nrows.div_ceil(nparts);
    let row_ranges = (0..nparts)
        .map(|p| (p * chunk, ((p + 1) * chunk).min(m.nrows)))
        .filter(|(lo, hi)| lo <= hi)
        .collect();
    Partition { kind: Kind::Rows, row_ranges, row_splits: vec![], col_splits: vec![] }
}

/// Nonzero-balanced row ranges — blocking *after* materialization
/// (Fig 4 right): split points placed on the materialized tuples so
/// every part carries ≈ nnz/nparts entries.
pub fn rows_balanced(m: &TriMat, nparts: usize) -> Partition {
    let nparts = nparts.max(1).min(m.nrows.max(1));
    let counts = m.row_counts();
    let total: usize = counts.iter().sum();
    let target = total.div_ceil(nparts);
    let mut row_ranges = Vec::with_capacity(nparts);
    let mut lo = 0usize;
    let mut acc = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target && row_ranges.len() + 1 < nparts {
            row_ranges.push((lo, i + 1));
            lo = i + 1;
            acc = 0;
        }
    }
    row_ranges.push((lo, m.nrows));
    Partition { kind: Kind::Rows, row_ranges, row_splits: vec![], col_splits: vec![] }
}

/// 2-D nonzero-balanced grid (Vastenhouw–Bisseling-style, simplified):
/// recursively choose row then column split points that halve the
/// nonzero count, `levels` times each.
pub fn grid_2d(m: &TriMat, levels: usize) -> Partition {
    let row_splits = balanced_splits(&m.row_counts(), 1 << levels);
    let col_splits = balanced_splits(&m.col_counts(), 1 << levels);
    Partition { kind: Kind::Grid2d, row_ranges: vec![], row_splits, col_splits }
}

/// Split points (excluding 0 and n) dividing `counts` into `parts`
/// nearly-equal prefix sums.
fn balanced_splits(counts: &[usize], parts: usize) -> Vec<usize> {
    let total: usize = counts.iter().sum();
    if parts <= 1 || total == 0 {
        return vec![];
    }
    let mut splits = Vec::with_capacity(parts - 1);
    let mut acc = 0usize;
    let mut next_target = total.div_ceil(parts);
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= next_target && splits.len() + 1 < parts {
            splits.push(i + 1);
            next_target = total * (splits.len() + 1) / parts;
        }
    }
    splits
}

/// nnz of each 2-D block (row-major over blocks) for a grid partition.
pub fn grid_block_nnz(m: &TriMat, p: &Partition) -> Vec<usize> {
    assert_eq!(p.kind, Kind::Grid2d);
    let rs = with_bounds(&p.row_splits, m.nrows);
    let cs = with_bounds(&p.col_splits, m.ncols);
    let nrb = rs.len() - 1;
    let ncb = cs.len() - 1;
    let mut nnz = vec![0usize; nrb * ncb];
    for e in &m.entries {
        let bi = rs.partition_point(|&s| s <= e.row as usize) - 1;
        let bj = cs.partition_point(|&s| s <= e.col as usize) - 1;
        nnz[bi * ncb + bj] += 1;
    }
    nnz
}

fn with_bounds(splits: &[usize], n: usize) -> Vec<usize> {
    let mut v = Vec::with_capacity(splits.len() + 2);
    v.push(0);
    v.extend_from_slice(splits);
    if *v.last().unwrap() != n {
        v.push(n);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn even_covers_all_rows() {
        let m = gen::uniform_random(103, 50, 400, 310);
        for n in [1, 3, 7, 103, 200] {
            let p = rows_even(&m, n);
            assert_eq!(p.row_ranges.first().unwrap().0, 0);
            assert_eq!(p.row_ranges.last().unwrap().1, 103);
            for w in p.row_ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn balanced_covers_and_balances() {
        let m = gen::powerlaw(400, 1.8, 150, 311);
        let p = rows_balanced(&m, 8);
        assert_eq!(p.row_ranges.len(), 8);
        assert_eq!(p.row_ranges.first().unwrap().0, 0);
        assert_eq!(p.row_ranges.last().unwrap().1, 400);
        let counts = m.row_counts();
        let nnz: Vec<usize> = p
            .row_ranges
            .iter()
            .map(|&(lo, hi)| counts[lo..hi].iter().sum())
            .collect();
        let max = *nnz.iter().max().unwrap() as f64;
        let mean = nnz.iter().sum::<usize>() as f64 / nnz.len() as f64;
        assert!(max / mean < 2.0, "imbalance {}", max / mean);
    }

    #[test]
    fn grid_blocks_partition_nnz() {
        let m = gen::uniform_random(128, 128, 2000, 312);
        let p = grid_2d(&m, 2); // 4×4 blocks
        let nnz = grid_block_nnz(&m, &p);
        assert_eq!(nnz.iter().sum::<usize>(), m.nnz());
        assert_eq!(nnz.len(), 16);
        // reasonably balanced for a uniform matrix
        let max = *nnz.iter().max().unwrap() as f64;
        let mean = m.nnz() as f64 / 16.0;
        assert!(max / mean < 2.0, "grid imbalance {}", max / mean);
    }

    #[test]
    fn degenerate_cases() {
        let empty = TriMat::new(5, 5);
        let p = rows_balanced(&empty, 4);
        assert_eq!(p.row_ranges.last().unwrap().1, 5);
        let g = grid_2d(&empty, 2);
        assert!(g.row_splits.is_empty());
    }
}
