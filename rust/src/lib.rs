//! # forelem
//!
//! Reproduction of Rietveld & Wijshoff, *Automatic Compiler-Based Data
//! Structure Generation* (CS.DC 2022), grown into an embeddable
//! compile-and-serve library: programs are specified over tuple
//! reservoirs with **no fixed data structure**, and the "compiler"
//! (this crate) derives both the loop nest and the physical data
//! structure, tunes the choice per matrix, and hands back a ready
//! executable.
//!
//! ## Quickstart
//!
//! The documented front door is [`engine::Engine`]: specification in,
//! tuned executable out.
//!
//! ```
//! use forelem::engine::{Engine, Kernel};
//! use forelem::matrix::TriMat;
//!
//! // A sparse matrix is just a reservoir of <row, col>_A tuples.
//! let mut a = TriMat::new(2, 2);
//! a.push(0, 0, 2.0);
//! a.push(1, 0, 1.0);
//! a.push(1, 1, 3.0);
//!
//! // Compile: enumerate -> calibrated predict -> prepare. Fallible —
//! // the only error is an invalid reservoir; everything else degrades
//! // down the `engine::Health` ladder instead.
//! let engine = Engine::builder().profile(false).build();
//! let exe = engine.compile(Kernel::Spmv, &a).unwrap();
//!
//! // Execute the generated routine on its generated data structure.
//! let mut y = [0.0; 2];
//! exe.spmv(&[1.0, 2.0], &mut y);
//! assert_eq!(y, [2.0, 7.0]);
//! println!("picked {} ({} bytes)\n{}", exe.plan().id, exe.bytes(), exe.explain());
//! ```
//!
//! `Engine::builder()` takes the architecture ([`Arch`]), an
//! [`engine::Autotune`] policy (`TopK(k)` measures the k best-predicted
//! plans and keeps the fastest, archiving every measurement for the
//! calibration loop), and auto-loads the machine's fitted tuning
//! profile (`target/tuning/<arch>.profile`, written by
//! `forelem calibrate`). Repeated compiles of the same matrix are
//! served from a process-wide plan + storage cache.
//!
//! ## Layers
//!
//! The engine fronts the layered pipeline (see DESIGN.md for the
//! diagram): `forelem` (specification IR) → `transforms` (the chain
//! steps of the paper) → `search` (tree enumeration, analytic cost
//! model, calibration) → `concretize` (layout mapping, storage
//! registry, codegen) → `storage`/`kernels` (the 13 formats behind the
//! `SparseOps` trait and their schedule-aware executors). The lower
//! layers stay public for the paper-reproduction surfaces
//! (`coordinator::sweep`, `bench::tables`, the CLI) and for tests, but
//! embedding users should not need anything below [`engine`].

pub mod chaos;
pub mod error;
pub mod matrix;
pub mod storage;
pub mod kernels;
pub mod baselines;
pub mod forelem;
pub mod transforms;
pub mod concretize;
pub mod search;
pub mod engine;
pub mod bench;
pub mod runtime;
pub mod coordinator;
pub mod distrib;
pub mod relational;
pub mod util;

// The crate's documented API surface — everything an embedding user
// needs, re-exported from one place.
pub use baselines::Kernel;
pub use coordinator::sweep::Arch;
pub use engine::{Autotune, CostBreakdown, Engine, Executable, Health};
pub use error::ForelemError;
