//! # forelem
//!
//! Reproduction of Rietveld & Wijshoff, *Automatic Compiler-Based Data
//! Structure Generation* (CS.DC 2022): the forelem framework — programs
//! specified over tuple reservoirs with no fixed data structure, from
//! which the "compiler" (this library) derives both loop nests and
//! physical data structures via chains of IR transformations, then
//! concretizes and executes them. See DESIGN.md for the experiment map.

pub mod matrix;
pub mod storage;
pub mod kernels;
pub mod baselines;
pub mod forelem;
pub mod transforms;
pub mod concretize;
pub mod search;
pub mod bench;
pub mod runtime;
pub mod coordinator;
pub mod distrib;
pub mod relational;
pub mod util;
