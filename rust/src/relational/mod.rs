//! Loop Collapse as a relational join generator (paper §5.1, §2.2.3).
//!
//! §5.1 collapses two forelem loops over reservoirs `T` and `R` with the
//! condition `r.b_field == t.a_field` into one loop over the combined
//! reservoir `TxR`, which materialization then turns into a single
//! physical sequence `PAxB` — "data that was originally stored in the
//! separate A and B structures … disassembled and reassembled into a
//! single data structure".
//!
//! As with the sparse formats, *different chains generate different
//! join algorithms* from the one specification:
//!
//! * no transformation        → nested-loop join (the collapsed cross
//!   product with the condition checked per pair);
//! * orthogonalization on the join field of `R` → index/hash join
//!   (the `R.b_field[v]` subsets become a materialized index);
//! * orthogonalization on both + encapsulated merge order → merge join
//!   (both reservoirs grouped by the join key, scanned in lockstep).
//!
//! All three produce the same `PAxB` multiset; the executors below are
//! the concretized codes, checked against each other in the tests.

use std::collections::HashMap;

/// A tuple of reservoir `T`: ⟨a_field, payload⟩.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TTuple {
    pub a_field: u32,
    pub a_val: f64,
}

/// A tuple of reservoir `R`: ⟨b_field, payload⟩.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RTuple {
    pub b_field: u32,
    pub b_val: f64,
}

/// A localized tuple of the collapsed reservoir `TxR`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JoinedTuple {
    pub key: u32,
    pub a_val: f64,
    pub b_val: f64,
}

/// Canonical sort for multiset comparison in tests/consumers that need a
/// deterministic order (the forelem semantics itself is unordered).
pub fn normalize(mut v: Vec<JoinedTuple>) -> Vec<JoinedTuple> {
    v.sort_by(|x, y| {
        (x.key, x.a_val, x.b_val)
            .partial_cmp(&(y.key, y.a_val, y.b_val))
            .unwrap()
    });
    v
}

/// Generated code 1 — the collapsed loop with no further transformation:
/// `forelem (t; t ∈ TxR.b_field[a_field]) …` concretized as a
/// nested-loop join over the unordered reservoirs.
pub fn join_nested_loop(t: &[TTuple], r: &[RTuple]) -> Vec<JoinedTuple> {
    let mut out = Vec::new();
    for tt in t {
        for rt in r {
            if rt.b_field == tt.a_field {
                out.push(JoinedTuple { key: tt.a_field, a_val: tt.a_val, b_val: rt.b_val });
            }
        }
    }
    out
}

/// Generated code 2 — orthogonalize `R` on `b_field` first: the subsets
/// `R.b_field[v]` materialize into an index keyed by the field value
/// (a hash join).
pub fn join_indexed(t: &[TTuple], r: &[RTuple]) -> Vec<JoinedTuple> {
    let mut index: HashMap<u32, Vec<f64>> = HashMap::new();
    for rt in r {
        index.entry(rt.b_field).or_default().push(rt.b_val);
    }
    let mut out = Vec::new();
    for tt in t {
        if let Some(bs) = index.get(&tt.a_field) {
            for &b in bs {
                out.push(JoinedTuple { key: tt.a_field, a_val: tt.a_val, b_val: b });
            }
        }
    }
    out
}

/// Generated code 3 — orthogonalize both reservoirs on the join field
/// and concretize the outer loops in ascending key order: a merge join.
pub fn join_merge(t: &[TTuple], r: &[RTuple]) -> Vec<JoinedTuple> {
    let mut ts = t.to_vec();
    let mut rs = r.to_vec();
    ts.sort_by_key(|x| x.a_field);
    rs.sort_by_key(|x| x.b_field);
    let mut out = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < ts.len() && j < rs.len() {
        let (ka, kb) = (ts[i].a_field, rs[j].b_field);
        if ka < kb {
            i += 1;
        } else if kb < ka {
            j += 1;
        } else {
            // emit the group cross product
            let j0 = j;
            while i < ts.len() && ts[i].a_field == ka {
                let mut jj = j0;
                while jj < rs.len() && rs[jj].b_field == ka {
                    out.push(JoinedTuple { key: ka, a_val: ts[i].a_val, b_val: rs[jj].b_val });
                    jj += 1;
                }
                i += 1;
            }
            while j < rs.len() && rs[j].b_field == ka {
                j += 1;
            }
        }
    }
    out
}

/// The materialized `PAxB` sequence (paper §5.1): localized joined
/// tuples in a single flat physical array — via the cheapest generated
/// plan (indexed).
pub fn materialize_paxb(t: &[TTuple], r: &[RTuple]) -> Vec<JoinedTuple> {
    join_indexed(t, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn gen_reservoirs(g: &mut Gen) -> (Vec<TTuple>, Vec<RTuple>) {
        let keys = g.usize_in(1, 20) as u32;
        let nt = g.usize_in(0, 60);
        let nr = g.usize_in(0, 60);
        let t = (0..nt)
            .map(|_| TTuple { a_field: g.usize_in(0, keys as usize) as u32, a_val: g.f64_in(-4.0, 4.0) })
            .collect();
        let r = (0..nr)
            .map(|_| RTuple { b_field: g.usize_in(0, keys as usize) as u32, b_val: g.f64_in(-4.0, 4.0) })
            .collect();
        (t, r)
    }

    #[test]
    fn all_generated_joins_agree() {
        forall("joins ≡", 60, |g| {
            let (t, r) = gen_reservoirs(g);
            let a = normalize(join_nested_loop(&t, &r));
            let b = normalize(join_indexed(&t, &r));
            let c = normalize(join_merge(&t, &r));
            if a != b {
                return Err(format!("indexed diverged: {} vs {}", a.len(), b.len()));
            }
            if a != c {
                return Err(format!("merge diverged: {} vs {}", a.len(), c.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn join_is_cross_product_per_key() {
        let t = vec![
            TTuple { a_field: 1, a_val: 10.0 },
            TTuple { a_field: 1, a_val: 11.0 },
            TTuple { a_field: 2, a_val: 20.0 },
        ];
        let r = vec![
            RTuple { b_field: 1, b_val: 0.1 },
            RTuple { b_field: 1, b_val: 0.2 },
            RTuple { b_field: 3, b_val: 0.3 },
        ];
        let out = normalize(join_indexed(&t, &r));
        assert_eq!(out.len(), 4); // 2 T-tuples × 2 R-tuples at key 1
        assert!(out.iter().all(|j| j.key == 1));
    }

    #[test]
    fn empty_reservoirs() {
        assert!(join_nested_loop(&[], &[]).is_empty());
        let t = vec![TTuple { a_field: 0, a_val: 1.0 }];
        assert!(join_merge(&t, &[]).is_empty());
    }

    #[test]
    fn paxb_is_single_flat_sequence() {
        let t = vec![TTuple { a_field: 7, a_val: 1.5 }];
        let r = vec![RTuple { b_field: 7, b_val: 2.5 }];
        let paxb = materialize_paxb(&t, &r);
        assert_eq!(paxb, vec![JoinedTuple { key: 7, a_val: 1.5, b_val: 2.5 }]);
    }
}
