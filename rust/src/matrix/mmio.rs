//! MatrixMarket coordinate-format I/O. The paper's evaluation uses 20
//! matrices from the UF (SuiteSparse) collection distributed as `.mtx`;
//! we support reading real files when available and writing our synthetic
//! suite out in the same format for inspection/interchange.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::ForelemError;
use crate::matrix::coo::TriMat;

#[derive(Debug)]
pub enum MmError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Unsupported(String),
    /// The file parsed, but the resulting reservoir violates the
    /// `TriMat` invariants (NaN/Inf values, degenerate dimensions) —
    /// see [`TriMat::validate`].
    Invalid(ForelemError),
}

impl std::fmt::Display for MmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmError::Io(e) => write!(f, "io: {e}"),
            MmError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            MmError::Unsupported(v) => write!(f, "unsupported MatrixMarket variant: {v}"),
            MmError::Invalid(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for MmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MmError::Io(e) => Some(e),
            MmError::Invalid(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MmError {
    fn from(e: std::io::Error) -> Self {
        MmError::Io(e)
    }
}

/// Parse MatrixMarket from a reader. Supports `matrix coordinate
/// real|integer|pattern general|symmetric|skew-symmetric`.
pub fn read_matrix_market<R: BufRead>(r: R) -> Result<TriMat, MmError> {
    let mut lines = r.lines().enumerate();

    // Header line.
    let (mut lineno, header) = loop {
        match lines.next() {
            Some((i, l)) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break (i, l);
                }
            }
            None => {
                return Err(MmError::Parse { line: 0, msg: "empty file".into() });
            }
        }
    };
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(MmError::Parse { line: lineno + 1, msg: format!("bad header '{header}'") });
    }
    if h[2] != "coordinate" {
        return Err(MmError::Unsupported(format!("format '{}'", h[2])));
    }
    let field = h[3].clone();
    if !matches!(field.as_str(), "real" | "integer" | "pattern") {
        return Err(MmError::Unsupported(format!("field '{field}'")));
    }
    let symmetry = h[4].clone();
    if !matches!(symmetry.as_str(), "general" | "symmetric" | "skew-symmetric") {
        return Err(MmError::Unsupported(format!("symmetry '{symmetry}'")));
    }

    // Size line (skipping comments).
    let size_line = loop {
        match lines.next() {
            Some((i, l)) => {
                lineno = i;
                let l = l?;
                let t = l.trim().to_string();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                break t;
            }
            None => return Err(MmError::Parse { line: lineno + 1, msg: "missing size line".into() }),
        }
    };
    let parts: Vec<&str> = size_line.split_whitespace().collect();
    if parts.len() != 3 {
        return Err(MmError::Parse { line: lineno + 1, msg: format!("bad size line '{size_line}'") });
    }
    let nrows: usize = parts[0].parse().map_err(|_| MmError::Parse { line: lineno + 1, msg: "bad nrows".into() })?;
    let ncols: usize = parts[1].parse().map_err(|_| MmError::Parse { line: lineno + 1, msg: "bad ncols".into() })?;
    let nnz: usize = parts[2].parse().map_err(|_| MmError::Parse { line: lineno + 1, msg: "bad nnz".into() })?;

    let mut m = TriMat::new(nrows, ncols);
    m.entries.reserve(if symmetry == "general" { nnz } else { nnz * 2 });
    let mut read = 0usize;
    for (i, l) in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or(MmError::Parse { line: i + 1, msg: "bad row index".into() })?;
        let c: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or(MmError::Parse { line: i + 1, msg: "bad col index".into() })?;
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            it.next()
                .and_then(|s| s.parse().ok())
                .ok_or(MmError::Parse { line: i + 1, msg: "bad value".into() })?
        };
        if r == 0 || c == 0 || r > nrows || c > ncols {
            return Err(MmError::Parse { line: i + 1, msg: format!("index ({r},{c}) out of bounds") });
        }
        m.push(r - 1, c - 1, v); // 1-based → 0-based
        match symmetry.as_str() {
            "symmetric" if r != c => m.push(c - 1, r - 1, v),
            "skew-symmetric" if r != c => m.push(c - 1, r - 1, -v),
            _ => {}
        }
        read += 1;
    }
    if read != nnz {
        return Err(MmError::Parse { line: 0, msg: format!("expected {nnz} entries, found {read}") });
    }
    m.sum_duplicates();
    // Rust's f64 parser happily accepts "nan" and "inf" tokens, and a
    // size line may declare degenerate dimensions — run the full
    // reservoir validation before handing the matrix to any consumer.
    m.validate().map_err(MmError::Invalid)?;
    Ok(m)
}

/// Read a `.mtx` file from disk.
pub fn read_file<P: AsRef<Path>>(path: P) -> Result<TriMat, MmError> {
    let f = std::fs::File::open(path)?;
    read_matrix_market(std::io::BufReader::new(f))
}

/// Write `m` in `matrix coordinate real general` format.
pub fn write_file<P: AsRef<Path>>(m: &TriMat, path: P) -> Result<(), MmError> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by forelem (synthetic suite)")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for e in &m.entries {
        writeln!(w, "{} {} {:.17e}", e.row + 1, e.col + 1, e.val)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let txt = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 2\n1 1 1.5\n3 2 -2.0\n";
        let m = read_matrix_market(Cursor::new(txt)).unwrap();
        assert_eq!((m.nrows, m.ncols, m.nnz()), (3, 3, 2));
        assert_eq!(m.to_dense()[0], 1.5);
        assert_eq!(m.to_dense()[3 * 2 + 1], -2.0);
    }

    #[test]
    fn parse_symmetric_expands() {
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 3.0\n2 1 4.0\n";
        let m = read_matrix_market(Cursor::new(txt)).unwrap();
        assert_eq!(m.nnz(), 3); // diagonal stays single
        let d = m.to_dense();
        assert_eq!(d[1], 4.0);
        assert_eq!(d[2], 4.0);
    }

    #[test]
    fn parse_pattern() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n2 2\n";
        let m = read_matrix_market(Cursor::new(txt)).unwrap();
        assert_eq!(m.to_dense()[3], 1.0);
    }

    #[test]
    fn rejects_bad_header_and_bounds() {
        assert!(read_matrix_market(Cursor::new("junk\n")).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market(Cursor::new(oob)).is_err());
        let wrong_count = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market(Cursor::new(wrong_count)).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let mut m = TriMat::new(4, 3);
        m.push(0, 0, 1.25);
        m.push(3, 2, -0.5);
        m.push(1, 1, 1e-9);
        let path = std::env::temp_dir().join("forelem_mmio_roundtrip.mtx");
        write_file(&m, &path).unwrap();
        let mut back = read_file(&path).unwrap();
        back.sort_row_major();
        let mut orig = m.clone();
        orig.sort_row_major();
        assert_eq!((back.nrows, back.ncols), (4, 3));
        assert_eq!(back.entries.len(), orig.entries.len());
        for (a, b) in back.entries.iter().zip(orig.entries.iter()) {
            assert_eq!((a.row, a.col), (b.row, b.col));
            assert!((a.val - b.val).abs() < 1e-15);
        }
        let _ = std::fs::remove_file(path);
    }
}
