//! Typed COO delta batches — the mutation half of the versioned-matrix
//! subsystem (`engine::version`). A [`DeltaBatch`] is a reservoir of
//! insert/update/delete tuples against one matrix generation; applying
//! it yields the canonical (row-major-sorted) post-delta reservoir, so
//! the new generation's fingerprint — and therefore every downstream
//! bit-identity contract — is deterministic regardless of the order the
//! caller recorded the ops in.
//!
//! # Semantics
//!
//! * **Insert** requires the coordinate to be absent from the target
//!   matrix; **Update** and **Delete** require it present. Violations
//!   are typed [`ForelemError::InvalidMatrix`] errors, per the engine's
//!   error taxonomy — a delta never silently no-ops.
//! * Several ops on the **same coordinate within one batch** resolve
//!   last-write-wins on the value (an `Insert` followed by an `Update`
//!   is an insert of the later value), **except** a batch that mixes a
//!   `Delete` with an `Insert`/`Update` on one coordinate: that is a
//!   genuinely conflicting pair (did the caller want the entry gone or
//!   present?) and resolution fails with a typed error instead of
//!   guessing.
//! * Values must be finite; indices must be in bounds; the batch's
//!   declared shape must match the target matrix exactly.

use std::collections::HashMap;

use crate::error::ForelemError;
use crate::matrix::{Entry, TriMat};

/// The three delta kinds a batch can carry per coordinate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaOp {
    /// Add a coordinate that is absent from the target matrix.
    Insert,
    /// Replace the value at a coordinate present in the target matrix.
    Update,
    /// Remove a coordinate present in the target matrix.
    Delete,
}

/// One resolved or recorded delta tuple. For `Delete` the value is
/// ignored (kept at 0.0 by the builders).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeltaEntry {
    pub row: u32,
    pub col: u32,
    pub val: f64,
    pub op: DeltaOp,
}

/// A batch of typed COO deltas against one matrix generation.
#[derive(Clone, Debug, Default)]
pub struct DeltaBatch {
    pub nrows: usize,
    pub ncols: usize,
    entries: Vec<DeltaEntry>,
}

impl DeltaBatch {
    /// Empty batch against an `nrows × ncols` generation.
    pub fn new(nrows: usize, ncols: usize) -> DeltaBatch {
        DeltaBatch { nrows, ncols, entries: Vec::new() }
    }

    pub fn insert(&mut self, row: usize, col: usize, val: f64) {
        self.push(row, col, val, DeltaOp::Insert);
    }

    pub fn update(&mut self, row: usize, col: usize, val: f64) {
        self.push(row, col, val, DeltaOp::Update);
    }

    pub fn delete(&mut self, row: usize, col: usize) {
        self.push(row, col, 0.0, DeltaOp::Delete);
    }

    fn push(&mut self, row: usize, col: usize, val: f64, op: DeltaOp) {
        debug_assert!(row < self.nrows && col < self.ncols, "delta out of bounds");
        self.entries.push(DeltaEntry { row: row as u32, col: col as u32, val, op });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The recorded ops, in insertion order (unresolved).
    pub fn entries(&self) -> &[DeltaEntry] {
        &self.entries
    }

    /// Resolve the batch to at most one op per coordinate, sorted by
    /// `(row, col)` — the form the per-format `SparseOps::repair`
    /// implementations and [`DeltaBatch::apply`] consume.
    ///
    /// Last-write-wins on the value; the resolved kind is `Delete` if
    /// only deletes touched the coordinate, `Insert` if any insert did,
    /// `Update` otherwise. Mixing `Delete` with `Insert`/`Update` on
    /// one coordinate is a conflict.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] on an out-of-bounds index, a
    /// non-finite insert/update value, or a conflicting
    /// insert+delete (or update+delete) pair on one coordinate.
    pub fn resolved(&self) -> Result<Vec<DeltaEntry>, ForelemError> {
        let bad = |reason: String| Err(ForelemError::InvalidMatrix(reason));
        let mut by_coord: HashMap<u64, DeltaEntry> = HashMap::new();
        for e in &self.entries {
            if e.row as usize >= self.nrows || e.col as usize >= self.ncols {
                return bad(format!(
                    "delta ({}, {}) out of bounds for {}x{}",
                    e.row, e.col, self.nrows, self.ncols
                ));
            }
            if e.op != DeltaOp::Delete && !e.val.is_finite() {
                return bad(format!("non-finite delta value at ({}, {})", e.row, e.col));
            }
            let key = ((e.row as u64) << 32) | e.col as u64;
            match by_coord.entry(key) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(*e);
                }
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    let prev = *o.get();
                    let deleting = e.op == DeltaOp::Delete;
                    let deleted = prev.op == DeltaOp::Delete;
                    if deleting != deleted {
                        return bad(format!(
                            "conflicting insert+delete pair at ({}, {}): one batch both \
                             removes and sets the coordinate",
                            e.row, e.col
                        ));
                    }
                    // Last write wins on the value; an Insert anywhere
                    // in the run keeps the resolved kind Insert (the
                    // coordinate is absent from the target either way).
                    let op = if prev.op == DeltaOp::Insert { DeltaOp::Insert } else { e.op };
                    o.insert(DeltaEntry { op, ..*e });
                }
            }
        }
        let mut out: Vec<DeltaEntry> = by_coord.into_values().collect();
        out.sort_unstable_by_key(|e| (e.row, e.col));
        Ok(out)
    }

    /// Apply the batch to `m`, producing the canonical
    /// (row-major-sorted) post-delta reservoir. The result is exactly
    /// the `TriMat` a from-scratch caller would build, so its
    /// fingerprint — and every storage assembled from it — is the
    /// reference the repair paths must match bit for bit.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] when the batch shape does not
    /// match `m`, on any resolution error ([`DeltaBatch::resolved`]),
    /// on an `Insert` of a coordinate already present, or an
    /// `Update`/`Delete` of a coordinate absent from `m`.
    pub fn apply(&self, m: &TriMat) -> Result<TriMat, ForelemError> {
        let bad = |reason: String| Err(ForelemError::InvalidMatrix(reason));
        if m.nrows != self.nrows || m.ncols != self.ncols {
            return bad(format!(
                "delta batch is {}x{} but the matrix is {}x{}",
                self.nrows, self.ncols, m.nrows, m.ncols
            ));
        }
        let resolved = self.resolved()?;
        let mut delta_at: HashMap<u64, DeltaEntry> = HashMap::with_capacity(resolved.len());
        for e in &resolved {
            delta_at.insert(((e.row as u64) << 32) | e.col as u64, *e);
        }
        let mut out: Vec<Entry> = Vec::with_capacity(m.entries.len() + resolved.len());
        let mut touched = 0usize;
        for e in &m.entries {
            let key = ((e.row as u64) << 32) | e.col as u64;
            match delta_at.get(&key) {
                None => out.push(*e),
                Some(d) => {
                    touched += 1;
                    match d.op {
                        DeltaOp::Insert => {
                            return bad(format!(
                                "insert at ({}, {}) but the coordinate is already present \
                                 (use update)",
                                e.row, e.col
                            ));
                        }
                        DeltaOp::Update => {
                            out.push(Entry { row: e.row, col: e.col, val: d.val })
                        }
                        DeltaOp::Delete => {}
                    }
                }
            }
        }
        if touched != resolved.iter().filter(|d| d.op != DeltaOp::Insert).count() {
            // Some Update/Delete never met a stored entry.
            for d in &resolved {
                if d.op == DeltaOp::Insert {
                    continue;
                }
                let present = m
                    .entries
                    .iter()
                    .any(|e| e.row == d.row && e.col == d.col);
                if !present {
                    return bad(format!(
                        "{} at ({}, {}) but the coordinate is absent (use insert)",
                        if d.op == DeltaOp::Update { "update" } else { "delete" },
                        d.row,
                        d.col
                    ));
                }
            }
        }
        for d in &resolved {
            if d.op == DeltaOp::Insert {
                out.push(Entry { row: d.row, col: d.col, val: d.val });
            }
        }
        let mut m2 = TriMat::with_entries(m.nrows, m.ncols, out);
        m2.sort_row_major();
        m2.validate()?;
        Ok(m2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TriMat {
        let mut m = TriMat::new(3, 3);
        m.push(0, 0, 1.0);
        m.push(1, 1, 2.0);
        m.push(2, 0, 3.0);
        m
    }

    #[test]
    fn apply_is_canonical_and_deterministic() {
        let m = small();
        let mut b = DeltaBatch::new(3, 3);
        b.insert(0, 2, 5.0);
        b.update(1, 1, -2.0);
        b.delete(2, 0);
        let m2 = b.apply(&m).expect("clean batch");
        assert_eq!(m2.nnz(), 3);
        let mut want = TriMat::new(3, 3);
        want.push(0, 0, 1.0);
        want.push(0, 2, 5.0);
        want.push(1, 1, -2.0);
        assert_eq!(m2.fingerprint(), want.fingerprint(), "canonical order drifted");
    }

    #[test]
    fn last_write_wins_within_a_batch() {
        let m = small();
        let mut b = DeltaBatch::new(3, 3);
        b.insert(0, 2, 5.0);
        b.update(0, 2, 7.0); // same coordinate, later op: value 7 wins, kind stays Insert
        b.update(1, 1, 4.0);
        b.update(1, 1, 6.0);
        let r = b.resolved().expect("no conflict");
        assert_eq!(r.len(), 2);
        assert_eq!(r[0], DeltaEntry { row: 0, col: 2, val: 7.0, op: DeltaOp::Insert });
        assert_eq!(r[1], DeltaEntry { row: 1, col: 1, val: 6.0, op: DeltaOp::Update });
        let m2 = b.apply(&m).expect("applies");
        assert!(m2.entries.iter().any(|e| e.row == 0 && e.col == 2 && e.val == 7.0));
        assert!(m2.entries.iter().any(|e| e.row == 1 && e.col == 1 && e.val == 6.0));
    }

    #[test]
    fn insert_delete_pair_is_a_typed_conflict() {
        let mut b = DeltaBatch::new(3, 3);
        b.insert(0, 2, 5.0);
        b.delete(0, 2);
        match b.resolved() {
            Err(ForelemError::InvalidMatrix(msg)) => {
                assert!(msg.contains("conflicting insert+delete"), "{msg}");
            }
            other => panic!("expected a typed conflict, got {other:?}"),
        }
        // Delete-then-update is the same ambiguity.
        let mut b2 = DeltaBatch::new(3, 3);
        b2.delete(1, 1);
        b2.update(1, 1, 9.0);
        assert!(b2.resolved().is_err());
    }

    #[test]
    fn presence_is_validated_per_op_kind() {
        let m = small();
        let mut ins = DeltaBatch::new(3, 3);
        ins.insert(0, 0, 9.0); // already present
        assert!(matches!(ins.apply(&m), Err(ForelemError::InvalidMatrix(_))));
        let mut upd = DeltaBatch::new(3, 3);
        upd.update(2, 2, 9.0); // absent
        assert!(matches!(upd.apply(&m), Err(ForelemError::InvalidMatrix(_))));
        let mut del = DeltaBatch::new(3, 3);
        del.delete(0, 1); // absent
        assert!(matches!(del.apply(&m), Err(ForelemError::InvalidMatrix(_))));
    }

    #[test]
    fn shape_mismatch_and_nonfinite_are_typed() {
        let m = small();
        let b = DeltaBatch::new(4, 3);
        assert!(matches!(b.apply(&m), Err(ForelemError::InvalidMatrix(_))));
        let mut nf = DeltaBatch::new(3, 3);
        nf.update(1, 1, f64::NAN);
        assert!(nf.resolved().is_err());
    }

    #[test]
    fn empty_batch_is_a_no_op_generation() {
        let m = small();
        let b = DeltaBatch::new(3, 3);
        let m2 = b.apply(&m).expect("empty batch applies");
        // Canonicalization may reorder, but `small()` is already
        // row-major, so the fingerprint is preserved exactly.
        assert_eq!(m2.fingerprint(), m.fingerprint());
    }
}
