//! Per-matrix structural statistics — the inputs of the analytic cost
//! model (`search::cost`). One `MatrixStats` value summarizes everything
//! the planner needs to *predict* a plan's execution time without
//! building any storage: nonzero count, the row-length distribution
//! (mean / variance / max — what decides CSR vs padded formats), the
//! bandwidth (what decides DIA and x-gather locality) and the density
//! (what decides register blocking fill-in).
//!
//! Computed in one pass by [`MatrixStats::of`]; the suite memoizes the
//! result per (matrix, scale) so sweeps, tables and the CLI never
//! recompute it (`matrix::suite::SuiteEntry::stats_scaled`).

use crate::matrix::TriMat;

/// Structural summary of a tuple reservoir.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatrixStats {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    /// Mean nonzeros per row (`nnz / nrows`).
    pub row_mean: f64,
    /// Population variance of the row-length distribution.
    pub row_var: f64,
    /// Maximum nonzeros in any row (the ELL padding width K).
    pub row_max: usize,
    /// Rows with no nonzeros at all.
    pub empty_rows: usize,
    /// Maximum `|col - row|` over all entries.
    pub bandwidth: usize,
    /// Mean `|col - row|` over all entries.
    pub avg_bandwidth: f64,
    /// `nnz / (nrows * ncols)`.
    pub density: f64,
    /// Dependence level count of the strictly-lower triangle (the TrSv
    /// critical path): rows partition into `dep_levels` waves of
    /// mutually independent solves. 1 = fully parallel, `nrows` = one
    /// serial chain. Caps the level-scheduled TrSv speedup.
    pub dep_levels: usize,
    /// Barrier waves the *supernoded* level schedule executes: maximal
    /// runs of adjacent levels narrower than
    /// `kernels::levels::SUPERNODE_MAX_WIDTH` merge into one serial
    /// wave (`kernels::levels` applies the same rule to the executable
    /// level sets). `sync_waves ≤ dep_levels`; drives the sync feature
    /// of the cost model.
    pub sync_waves: usize,
}

impl MatrixStats {
    /// Compute the statistics from a reservoir (one pass over the
    /// entries plus one over the row counts).
    pub fn of(m: &TriMat) -> Self {
        let nrows = m.nrows;
        let ncols = m.ncols;
        let nnz = m.nnz();
        let counts = m.row_counts();
        let row_max = counts.iter().copied().max().unwrap_or(0);
        let empty_rows = counts.iter().filter(|&&c| c == 0).count();
        let nr = nrows.max(1) as f64;
        let row_mean = nnz as f64 / nr;
        let row_var = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - row_mean;
                d * d
            })
            .sum::<f64>()
            / nr;
        let mut bandwidth = 0usize;
        let mut band_sum = 0u64;
        for e in &m.entries {
            let b = (e.row as i64 - e.col as i64).unsigned_abs() as usize;
            bandwidth = bandwidth.max(b);
            band_sum += b as u64;
        }
        let avg_bandwidth = band_sum as f64 / (nnz.max(1)) as f64;
        let density = nnz as f64 / (nr * ncols.max(1) as f64);
        let (dep_levels, sync_waves) = dep_structure(m);
        MatrixStats {
            nrows,
            ncols,
            nnz,
            row_mean,
            row_var,
            row_max,
            empty_rows,
            bandwidth,
            avg_bandwidth,
            density,
            dep_levels,
            sync_waves,
        }
    }

    /// Build synthetic statistics directly (cost-model tests and the
    /// reference ranking point used when no matrix is at hand yet).
    pub fn synthetic(
        nrows: usize,
        ncols: usize,
        row_mean: f64,
        row_var: f64,
        row_max: usize,
        bandwidth: usize,
    ) -> Self {
        let nnz = (row_mean * nrows as f64).round() as usize;
        MatrixStats {
            nrows,
            ncols,
            nnz,
            row_mean,
            row_var,
            row_max,
            empty_rows: 0,
            bandwidth,
            avg_bandwidth: bandwidth as f64 * 0.5,
            density: nnz as f64 / (nrows.max(1) * ncols.max(1)) as f64,
            // Pessimistic default: a full serial chain. Tests that
            // exercise the TrSv level term override via
            // `with_dep_levels`. With uniform width-1 levels the
            // supernode rule merges everything into a single wave.
            dep_levels: nrows.max(1),
            sync_waves: 1,
        }
    }

    /// `self` with the TrSv dependence level count replaced (synthetic
    /// statistics for the cost-model tests). `sync_waves` follows the
    /// supernode rule under the uniform-width assumption: levels of
    /// mean width ≤ the supernode threshold all merge into one wave.
    pub fn with_dep_levels(mut self, dep_levels: usize) -> Self {
        self.dep_levels = dep_levels.max(1);
        self.sync_waves =
            if self.level_width() <= crate::kernels::levels::SUPERNODE_MAX_WIDTH as f64 {
                1
            } else {
                self.dep_levels
            };
        self
    }

    /// The "typical suite matrix" used to rank plans when no concrete
    /// matrix has been chosen yet: mid-size, irregular row fill,
    /// unstructured column pattern.
    pub fn nominal() -> Self {
        MatrixStats::synthetic(4000, 4000, 15.0, 225.0, 400, 2000)
    }

    /// Coefficient of variation of the row lengths (`σ / mean`) — the
    /// planner's irregularity signal (0 for perfectly uniform rows).
    pub fn row_cv(&self) -> f64 {
        if self.row_mean <= 0.0 {
            return 0.0;
        }
        self.row_var.max(0.0).sqrt() / self.row_mean
    }

    /// ELL padding factor: stored slots over nonzeros (`nrows * row_max
    /// / nnz`, ≥ 1; 1 for uniform rows).
    pub fn ell_fill(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        (self.nrows * self.row_max) as f64 / self.nnz as f64
    }

    /// Mean rows per dependence level — the average parallel width a
    /// level-scheduled TrSv can exploit.
    pub fn level_width(&self) -> f64 {
        self.nrows.max(1) as f64 / self.dep_levels.max(1) as f64
    }
}

/// Dependence structure of `m`'s strictly-lower triangle: `(level
/// count, supernoded wave count)`. Only entries with `col < row`
/// participate — for the lowered TrSv operand that is every entry. One
/// counting-sort pass groups the lower columns by row, then the level
/// assignment *and* the wave merge rule shared with the executable
/// level sets (`kernels::levels::assign_levels` / `count_waves`) run
/// over the CSR-shaped arrays, so the estimate cannot drift from
/// `LevelSets::from_csr` on strictly-lower storage.
fn dep_structure(m: &TriMat) -> (usize, usize) {
    let n = m.nrows;
    if n == 0 {
        return (1, 1);
    }
    let mut row_ptr = vec![0u32; n + 1];
    for e in &m.entries {
        if (e.col as usize) < (e.row as usize) {
            row_ptr[e.row as usize + 1] += 1;
        }
    }
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    let mut cols = vec![0u32; row_ptr[n] as usize];
    let mut next = row_ptr.clone();
    for e in &m.entries {
        if (e.col as usize) < (e.row as usize) {
            cols[next[e.row as usize] as usize] = e.col;
            next[e.row as usize] += 1;
        }
    }
    let level = crate::kernels::levels::assign_levels(&row_ptr, &cols);
    let nlevels = level.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut widths = vec![0usize; nlevels];
    for &l in &level {
        widths[l as usize] += 1;
    }
    (nlevels, crate::kernels::levels::count_waves(&widths))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn uniform_rows_have_zero_variance() {
        let mut m = TriMat::new(6, 8);
        for i in 0..6 {
            m.push(i, i, 1.0);
            m.push(i, (i + 1) % 8, 2.0);
        }
        let s = MatrixStats::of(&m);
        assert_eq!(s.nnz, 12);
        assert!((s.row_mean - 2.0).abs() < 1e-12);
        assert!(s.row_var.abs() < 1e-12);
        assert_eq!(s.row_max, 2);
        assert_eq!(s.empty_rows, 0);
        assert!((s.row_cv()).abs() < 1e-12);
        assert!((s.ell_fill() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_rows_show_up_in_variance_and_fill() {
        let mut m = TriMat::new(10, 40);
        for j in 0..40 {
            m.push(0, j, 1.0); // one dense row
        }
        m.push(5, 0, 1.0);
        let s = MatrixStats::of(&m);
        assert_eq!(s.row_max, 40);
        assert_eq!(s.empty_rows, 8);
        assert!(s.row_cv() > 2.0, "cv = {}", s.row_cv());
        assert!(s.ell_fill() > 5.0, "fill = {}", s.ell_fill());
    }

    #[test]
    fn bandwidth_of_banded_matrix_is_small() {
        let banded = gen::banded(200, 5, 0.8, 77);
        let s = MatrixStats::of(&banded);
        assert!(s.bandwidth <= 5, "bandwidth = {}", s.bandwidth);
        assert!(s.avg_bandwidth <= 5.0);
        let random = gen::uniform_random(200, 200, 800, 78);
        let r = MatrixStats::of(&random);
        assert!(r.bandwidth > 50, "random bandwidth = {}", r.bandwidth);
    }

    #[test]
    fn empty_matrix_is_safe() {
        let s = MatrixStats::of(&TriMat::new(6, 6));
        assert_eq!(s.nnz, 0);
        assert_eq!(s.row_max, 0);
        assert_eq!(s.empty_rows, 6);
        assert_eq!(s.row_cv(), 0.0);
        assert_eq!(s.ell_fill(), 1.0);
        assert_eq!(s.density, 0.0);
        assert_eq!(s.dep_levels, 1);
        assert_eq!(s.sync_waves, 1);
        assert_eq!(s.level_width(), 6.0);
    }

    #[test]
    fn dep_levels_track_the_lower_critical_path() {
        // Single chain: x[i] depends on x[i-1] → n levels.
        let mut chain = TriMat::new(10, 10);
        for i in 1..10 {
            chain.push(i, i - 1, 1.0);
        }
        let cs = MatrixStats::of(&chain);
        assert_eq!(cs.dep_levels, 10);
        // Width-1 levels all merge into a single supernoded wave.
        assert_eq!(cs.sync_waves, 1);
        // Strictly-upper entries carry no TrSv dependence.
        let mut upper = TriMat::new(10, 10);
        for i in 1..10 {
            upper.push(i - 1, i, 1.0);
        }
        assert_eq!(MatrixStats::of(&upper).dep_levels, 1);
        // One fan-in row: everything else is level 0.
        let mut fan = TriMat::new(10, 10);
        for j in 0..9 {
            fan.push(9, j, 1.0);
        }
        let s = MatrixStats::of(&fan);
        assert_eq!(s.dep_levels, 2);
        assert_eq!(s.level_width(), 5.0);
        // Level 0 is wide (9 rows), level 1 is the narrow fan-in row:
        // 2 waves (a narrow run never merges into a wide neighbor).
        assert_eq!(s.sync_waves, 2);
        // Matches the executable level sets on a lowered matrix.
        let l = gen::uniform_random(30, 30, 180, 12).strictly_lower();
        let lv = crate::kernels::levels::LevelSets::from_csr(
            &crate::storage::Csr::from_tuples(&l),
        );
        let ls = MatrixStats::of(&l);
        assert_eq!(ls.dep_levels, lv.nlevels());
        assert_eq!(ls.sync_waves, lv.nwaves());
        assert!(ls.sync_waves <= ls.dep_levels);
        // Synthetic stats default to the pessimistic serial chain
        // (whose uniform width-1 levels supernode into one wave).
        let syn = MatrixStats::synthetic(100, 100, 4.0, 1.0, 8, 50);
        assert_eq!(syn.dep_levels, 100);
        assert_eq!(syn.sync_waves, 1);
        let wide = syn.with_dep_levels(4);
        assert_eq!(wide.dep_levels, 4);
        assert_eq!(wide.sync_waves, 4); // width 25 > threshold: no merge
    }

    #[test]
    fn synthetic_matches_definitions() {
        let s = MatrixStats::synthetic(1000, 1000, 8.0, 0.0, 8, 500);
        assert_eq!(s.nnz, 8000);
        assert!((s.density - 8e-3).abs() < 1e-12);
        assert!((s.ell_fill() - 1.0).abs() < 1e-12);
    }
}
