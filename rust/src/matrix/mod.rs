//! Sparse-matrix substrate: the tuple ("disassembled") representation,
//! MatrixMarket I/O, synthetic structural generators and the paper's
//! 20-matrix evaluation suite.

pub mod coo;
pub mod delta;
pub mod gen;
pub mod mmio;
pub mod stats;
pub mod suite;

pub use coo::{Entry, TriMat};
pub use stats::MatrixStats;
