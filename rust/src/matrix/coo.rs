//! Triplet ("tuple") sparse matrix — the *disassembled* form of the paper:
//! a sparse matrix is exactly a reservoir of `⟨row, col⟩_A` token tuples
//! with the value `A(row, col)` attached (paper §2.2.2). Every generated
//! data structure in `storage/` is (re)assembled from this type.

use crate::error::ForelemError;
use crate::util::rng::Rng;

/// One nonzero entry: the token tuple `⟨row, col⟩` plus its data value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub row: u32,
    pub col: u32,
    pub val: f64,
}

/// A sparse matrix as an unordered multiset-free collection of entries.
/// Invariant (checked by `validate`): no duplicate (row, col) pairs,
/// all indices in bounds.
#[derive(Clone, Debug, Default)]
pub struct TriMat {
    pub nrows: usize,
    pub ncols: usize,
    pub entries: Vec<Entry>,
}

impl TriMat {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        TriMat { nrows, ncols, entries: Vec::new() }
    }

    pub fn with_entries(nrows: usize, ncols: usize, entries: Vec<Entry>) -> Self {
        TriMat { nrows, ncols, entries }
    }

    /// Construct a validated reservoir from raw COO entries that may
    /// contain duplicate coordinates, **summing** duplicates into one
    /// entry (the MatrixMarket convention). This is the documented
    /// constructor path for material [`validate`](TriMat::validate)
    /// would reject wholesale — feeds that legitimately repeat
    /// coordinates, like accumulation streams or concatenated COO
    /// shards. (Delta batches are different: within one
    /// [`crate::matrix::delta::DeltaBatch`] repeated coordinates
    /// resolve **last-write-wins**, and a conflicting insert+delete
    /// pair is a typed error — see `matrix::delta`.)
    ///
    /// The result is canonical: duplicates merged, entries sorted
    /// row-major, invariants checked.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] on a degenerate shape, an
    /// out-of-bounds entry, or a non-finite value (including a sum of
    /// duplicates that overflows to ±∞).
    pub fn from_coo_summing(
        nrows: usize,
        ncols: usize,
        entries: Vec<Entry>,
    ) -> Result<Self, ForelemError> {
        let mut m = TriMat { nrows, ncols, entries };
        m.sum_duplicates();
        m.validate()?;
        Ok(m)
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        debug_assert!(row < self.nrows && col < self.ncols);
        self.entries.push(Entry { row: row as u32, col: col as u32, val });
    }

    /// Check the reservoir invariants: sane dimensions (nonzero, no
    /// `u32`/`usize` overflow), in-bounds indices, no duplicate
    /// `(row, col)` pairs, finite values. Every ingestion seam
    /// (`mmio`, the generators, `Engine::compile`) runs this, so a bad
    /// reservoir is rejected with a typed
    /// [`ForelemError::InvalidMatrix`] before any storage is built.
    pub fn validate(&self) -> Result<(), ForelemError> {
        let bad = |reason: String| Err(ForelemError::InvalidMatrix(reason));
        if self.nrows == 0 || self.ncols == 0 {
            return bad(format!("zero dimension: {}x{}", self.nrows, self.ncols));
        }
        // Entries index with u32 tokens; dense workspaces take
        // nrows*ncols products. Refuse shapes those cannot address.
        if self.nrows > u32::MAX as usize || self.ncols > u32::MAX as usize {
            return bad(format!("dimension exceeds u32 index space: {}x{}", self.nrows, self.ncols));
        }
        if self.nrows.checked_mul(self.ncols).is_none() {
            return bad(format!("dimension product overflows: {}x{}", self.nrows, self.ncols));
        }
        let mut seen = std::collections::HashSet::with_capacity(self.nnz() * 2);
        for e in &self.entries {
            if e.row as usize >= self.nrows || e.col as usize >= self.ncols {
                return bad(format!(
                    "entry ({}, {}) out of bounds {}x{}",
                    e.row, e.col, self.nrows, self.ncols
                ));
            }
            if !seen.insert(((e.row as u64) << 32) | e.col as u64) {
                return bad(format!("duplicate entry ({}, {})", e.row, e.col));
            }
            if !e.val.is_finite() {
                return bad(format!("non-finite value at ({}, {})", e.row, e.col));
            }
        }
        Ok(())
    }

    /// Merge duplicate coordinates by summing their values (MatrixMarket
    /// files and generators may produce duplicates).
    pub fn sum_duplicates(&mut self) {
        let mut map = std::collections::HashMap::with_capacity(self.nnz() * 2);
        for e in &self.entries {
            *map.entry(((e.row as u64) << 32) | e.col as u64).or_insert(0.0) += e.val;
        }
        let mut entries: Vec<Entry> = map
            .into_iter()
            .map(|(k, v)| Entry { row: (k >> 32) as u32, col: (k & 0xFFFF_FFFF) as u32, val: v })
            .collect();
        entries.sort_unstable_by_key(|e| (e.row, e.col));
        self.entries = entries;
    }

    /// Row-major sort (row, then col).
    pub fn sort_row_major(&mut self) {
        self.entries.sort_unstable_by_key(|e| (e.row, e.col));
    }

    /// Column-major sort (col, then row).
    pub fn sort_col_major(&mut self) {
        self.entries.sort_unstable_by_key(|e| (e.col, e.row));
    }

    /// Shuffle entries — used by tests to confirm order-insensitivity of
    /// the forelem pipeline ("iteration order explicitly undefined").
    pub fn shuffle(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.entries);
    }

    /// Order-sensitive 64-bit FNV-1a content fingerprint over the
    /// shape and every `⟨row, col, value-bits⟩` tuple — the matrix
    /// identity key of the engine's process-wide compile cache
    /// (`forelem::engine`). Two reservoirs with identical entries in
    /// identical order fingerprint identically; a reordered reservoir
    /// is a different key (storages assembled from it may differ
    /// bit-for-bit, e.g. unsorted COO), which keeps the cache exact
    /// rather than merely probable.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.eat_u64(self.nrows as u64);
        h.eat_u64(self.ncols as u64);
        h.eat_u64(self.entries.len() as u64);
        for e in &self.entries {
            h.eat_u64(((e.row as u64) << 32) | e.col as u64);
            h.eat_u64(e.val.to_bits());
        }
        h.finish()
    }

    /// Number of nonzeros per row.
    pub fn row_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.nrows];
        for e in &self.entries {
            c[e.row as usize] += 1;
        }
        c
    }

    /// Number of nonzeros per column.
    pub fn col_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.ncols];
        for e in &self.entries {
            c[e.col as usize] += 1;
        }
        c
    }

    /// Maximum nonzeros in any row (the ITPACK/ELL width K).
    pub fn max_row_nnz(&self) -> usize {
        self.row_counts().into_iter().max().unwrap_or(0)
    }

    /// Extract the unit-lower-triangular system used by the TrSv
    /// experiments: strictly-lower part of `self` (diagonal implied 1).
    pub fn strictly_lower(&self) -> TriMat {
        let entries = self
            .entries
            .iter()
            .copied()
            .filter(|e| e.col < e.row)
            .collect();
        TriMat { nrows: self.nrows, ncols: self.ncols, entries }
    }

    /// Transpose (swaps the token fields of every tuple).
    pub fn transpose(&self) -> TriMat {
        TriMat {
            nrows: self.ncols,
            ncols: self.nrows,
            entries: self
                .entries
                .iter()
                .map(|e| Entry { row: e.col, col: e.row, val: e.val })
                .collect(),
        }
    }

    /// Dense row-major expansion (oracle-sized matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for e in &self.entries {
            d[e.row as usize * self.ncols + e.col as usize] += e.val;
        }
        d
    }

    /// Dense-oracle SpMV: `y = A x` computed from the dense expansion-free
    /// triplet walk (order independent, exact reference).
    pub fn spmv_ref(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for e in &self.entries {
            y[e.row as usize] += e.val * x[e.col as usize];
        }
        y
    }

    /// Dense-oracle SpMM: `C = A B` with `B` dense `ncols × k`, row-major.
    pub fn spmm_ref(&self, b: &[f64], k: usize) -> Vec<f64> {
        assert_eq!(b.len(), self.ncols * k);
        let mut c = vec![0.0; self.nrows * k];
        for e in &self.entries {
            let (r, cc, v) = (e.row as usize, e.col as usize, e.val);
            let brow = &b[cc * k..cc * k + k];
            let crow = &mut c[r * k..r * k + k];
            for j in 0..k {
                crow[j] += v * brow[j];
            }
        }
        c
    }

    /// Oracle unit-lower triangular solve `L x = b` where `L` has implied
    /// unit diagonal and `self` holds the strictly-lower entries.
    pub fn trsv_unit_lower_ref(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(b.len(), self.nrows);
        // Gather strictly-lower entries by row, then forward substitution.
        let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.nrows];
        for e in &self.entries {
            assert!(e.col < e.row, "trsv oracle expects strictly-lower input");
            rows[e.row as usize].push((e.col as usize, e.val));
        }
        let mut x = b.to_vec();
        for i in 0..self.nrows {
            let mut s = 0.0;
            for &(j, v) in &rows[i] {
                s += v * x[j];
            }
            x[i] -= s;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TriMat {
        // [ 1 0 2 ]
        // [ 0 3 0 ]
        // [ 4 0 5 ]
        let mut m = TriMat::new(3, 3);
        m.push(0, 0, 1.0);
        m.push(0, 2, 2.0);
        m.push(1, 1, 3.0);
        m.push(2, 0, 4.0);
        m.push(2, 2, 5.0);
        m
    }

    #[test]
    fn validate_ok_and_duplicates() {
        let mut m = small();
        assert!(m.validate().is_ok());
        m.push(0, 0, 9.0);
        assert!(m.validate().is_err());
        m.sum_duplicates();
        assert!(m.validate().is_ok());
        assert_eq!(m.nnz(), 5);
        let d = m.to_dense();
        assert_eq!(d[0], 10.0); // 1 + 9
    }

    #[test]
    fn from_coo_summing_merges_and_validates() {
        let entries = vec![
            Entry { row: 0, col: 0, val: 1.0 },
            Entry { row: 0, col: 0, val: 9.0 }, // duplicate: summed
            Entry { row: 1, col: 2, val: 2.0 },
        ];
        let m = TriMat::from_coo_summing(2, 3, entries).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense()[0], 10.0);
        // Typed errors, not panics, on hostile material.
        let oob = vec![Entry { row: 5, col: 0, val: 1.0 }];
        assert!(TriMat::from_coo_summing(2, 3, oob).is_err());
        assert!(TriMat::from_coo_summing(0, 3, vec![]).is_err());
        let inf = vec![
            Entry { row: 0, col: 0, val: f64::MAX },
            Entry { row: 0, col: 0, val: f64::MAX }, // sums to +inf
        ];
        assert!(TriMat::from_coo_summing(2, 3, inf).is_err());
    }

    #[test]
    fn validate_rejects_degenerate_shapes() {
        assert!(TriMat::new(0, 3).validate().is_err(), "zero rows");
        assert!(TriMat::new(3, 0).validate().is_err(), "zero cols");
        assert!(TriMat::new(u32::MAX as usize + 1, 1).validate().is_err(), "u32 overflow");
        assert!(TriMat::new(usize::MAX / 2, 3).validate().is_err(), "unaddressable shape");
        let e = TriMat::new(0, 0).validate().unwrap_err();
        assert_eq!(e.class(), "invalid-matrix");
    }

    #[test]
    fn spmv_oracle() {
        let m = small();
        let y = m.spmv_ref(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn spmm_oracle_matches_repeated_spmv() {
        let m = small();
        let k = 2;
        let b = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]; // 3x2 row-major
        let c = m.spmm_ref(&b, k);
        for j in 0..k {
            let x: Vec<f64> = (0..3).map(|i| b[i * k + j]).collect();
            let y = m.spmv_ref(&x);
            for i in 0..3 {
                assert!((c[i * k + j] - y[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let m = small();
        let mut tt = m.transpose().transpose();
        tt.sort_row_major();
        let mut orig = m.clone();
        orig.sort_row_major();
        assert_eq!(tt.entries, orig.entries);
    }

    #[test]
    fn lower_and_trsv() {
        // L = I + strictly lower [[0,0],[2,0]]
        let mut m = TriMat::new(2, 2);
        m.push(1, 0, 2.0);
        m.push(0, 1, 7.0); // upper entry must be filtered by strictly_lower
        let l = m.strictly_lower();
        assert_eq!(l.nnz(), 1);
        let x = l.trsv_unit_lower_ref(&[1.0, 5.0]);
        assert_eq!(x, vec![1.0, 3.0]);
    }

    #[test]
    fn counts() {
        let m = small();
        assert_eq!(m.row_counts(), vec![2, 1, 2]);
        assert_eq!(m.col_counts(), vec![2, 1, 2]);
        assert_eq!(m.max_row_nnz(), 2);
    }

    #[test]
    fn spmv_order_independent() {
        let mut m = small();
        let x = vec![0.5, -1.5, 2.0];
        let y0 = m.spmv_ref(&x);
        let mut rng = Rng::new(99);
        m.shuffle(&mut rng);
        let y1 = m.spmv_ref(&x);
        assert_eq!(y0, y1);
    }

    #[test]
    fn fingerprint_tracks_content_shape_and_order() {
        let m = small();
        assert_eq!(m.fingerprint(), small().fingerprint(), "must be deterministic");
        // Any content change moves the fingerprint.
        let mut v = small();
        v.entries[0].val += 1e-300;
        assert_ne!(m.fingerprint(), v.fingerprint());
        let mut c = small();
        c.entries[0].col = 1;
        assert_ne!(m.fingerprint(), c.fingerprint());
        // Shape participates even with identical entries.
        let mut wide = small();
        wide.ncols = 4;
        assert_ne!(m.fingerprint(), wide.fingerprint());
        // Order-sensitive by design (reassembled storages may differ).
        let mut swapped = small();
        swapped.entries.swap(0, 1);
        assert_ne!(m.fingerprint(), swapped.fingerprint());
    }
}
