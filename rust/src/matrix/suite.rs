//! The 20-matrix evaluation suite. The paper uses 20 matrices from the
//! University of Florida (SuiteSparse) collection; offline we substitute
//! synthetic matrices of the same *structural class*, keyed by the same
//! names, scaled to laptop size (DESIGN.md §5). If a real `.mtx` file is
//! present under `$FORELEM_MATRIX_DIR/<name>.mtx` it is used instead.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::matrix::coo::TriMat;
use crate::matrix::stats::MatrixStats;
use crate::matrix::{gen, mmio};

/// Structural class of a suite matrix (documents the substitution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Graph,
    PowerLaw,
    Banded,
    Stencil,
    FemBlocks,
    Constraint,
    Circuit,
    Planar,
}

/// A named matrix of the evaluation suite.
#[derive(Clone, Copy, Debug)]
pub struct SuiteEntry {
    /// UF-collection name used by the paper's tables.
    pub name: &'static str,
    pub class: Class,
    /// Deterministic seed so every run benchmarks identical matrices.
    pub seed: u64,
}

impl SuiteEntry {
    /// Instantiate the matrix (synthetic, or from disk if provided) at
    /// the env-default scale (`FORELEM_SUITE_SCALE`, default 1.0).
    pub fn build(&self) -> TriMat {
        self.build_scaled(env_scale())
    }

    /// Instantiate at an explicit scale factor — the coordinator's two
    /// "architectures" use different scales (DESIGN.md §5).
    pub fn build_scaled(&self, scale: f64) -> TriMat {
        if let Ok(dir) = std::env::var("FORELEM_MATRIX_DIR") {
            let p = std::path::Path::new(&dir).join(format!("{}.mtx", self.name));
            if p.exists() {
                if let Ok(m) = mmio::read_file(&p) {
                    return m;
                }
            }
        }
        SCALE.with(|s| s.set(scale));
        synthesize(self.name, self.class, self.seed)
    }

    /// Structural statistics at the env-default scale (memoized).
    pub fn stats(&self) -> MatrixStats {
        self.stats_scaled(env_scale())
    }

    /// Structural statistics at an explicit scale — memoized per
    /// (matrix, scale), so the planner (`coordinator::sweep`), the
    /// paper tables and the `suite` CLI all share one computation
    /// instead of rebuilding the matrix to recount rows.
    pub fn stats_scaled(&self, scale: f64) -> MatrixStats {
        static MEMO: OnceLock<Mutex<HashMap<(&'static str, u64), MatrixStats>>> = OnceLock::new();
        let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
        let key = (self.name, scale.to_bits());
        if let Some(s) = memo.lock().unwrap().get(&key) {
            return *s;
        }
        let s = MatrixStats::of(&self.build_scaled(scale));
        memo.lock().unwrap().insert(key, s);
        s
    }
}

/// Env-default scale knob: 1.0 reproduces the default sizes below.
fn env_scale() -> f64 {
    std::env::var("FORELEM_SUITE_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

thread_local! {
    static SCALE: std::cell::Cell<f64> = const { std::cell::Cell::new(1.0) };
}

fn s(n: usize) -> usize {
    let scale = SCALE.with(|s| s.get());
    ((n as f64 * scale) as usize).max(32)
}

/// Build the synthetic stand-in for a named UF matrix. Parameters are
/// chosen to mirror the original's structural statistics (row-fill
/// distribution, bandwidth, blocking), scaled down ~10–30×.
fn synthesize(name: &str, class: Class, seed: u64) -> TriMat {
    match (name, class) {
        // Pajek Erdős collaboration graph: tiny, very irregular.
        ("Erdos971", _) => gen::erdos_renyi(s(472), 5.6, seed),
        // FEM discretization, mid-bandwidth.
        ("mcfe", _) => gen::banded(s(765), 24, 0.55, seed),
        // Structural problem, narrow band.
        ("blckhole", _) => gen::banded(s(2132), 6, 0.75, seed),
        // LP constraint matrix with dense coupling rows.
        ("c-62", _) => gen::constraint(s(4000), 24, 600, seed),
        // Optimal power-flow network.
        ("OPF_10000", _) => gen::circuit(s(8000), 12, 120, seed),
        // Chemical-process simulation: skewed constraint structure.
        ("lhr71", _) => gen::constraint(s(7000), 40, 300, seed),
        // Bio-engineering (stomach): 3-D stencil regularity.
        ("stomach", _) => gen::laplacian_2d(s(110), s(110), seed),
        // Oil-reservoir FDM, classic banded.
        ("Orsreg_1", _) => gen::banded(s(2205), 10, 0.6, seed),
        // Ship-section FEM: dense node blocks.
        ("shipsec1", _) => gen::fem_blocks(s(2300), 3, 6, seed),
        ("shipsec5", _) => gen::fem_blocks(s(2900), 3, 6, seed),
        // Protein structure: very dense rows, blocks.
        ("pdb1HYS", _) => gen::fem_blocks(s(1200), 4, 10, seed),
        // Census redistricting adjacency: planar, short rows.
        ("or2010", _) => gen::planar_adjacency(s(9000), seed),
        // Semiconductor device FEM.
        ("Para-4", _) => gen::fem_blocks(s(2600), 3, 5, seed),
        // Large circuit: power-law + symmetric stencil.
        ("G2_circuit", _) => gen::circuit(s(9000), 20, 200, seed),
        // Graph-partitioning mesh ("144"): near-constant degree mesh.
        ("144", _) => gen::erdos_renyi(s(9000), 15.0, seed),
        // Accelerator cavity FEM.
        ("cop20k_A", _) => gen::fem_blocks(s(2400), 3, 7, seed),
        // Concentric spheres FEM: the densest rows in the suite.
        ("consph", _) => gen::fem_blocks(s(1400), 6, 8, seed),
        // Circuit simulation with strong hubs.
        ("Raj1", _) => gen::powerlaw(s(9000), 1.9, 400, seed),
        // CFD 3-D tube: stencil + blocks.
        ("3dtube", _) => gen::fem_blocks(s(1900), 4, 6, seed),
        // Network optimization: dense coupling rows.
        ("net150", _) => gen::constraint(s(4300), 60, 500, seed),
        (other, class) => fallback(other, class, seed),
    }
}

fn fallback(_name: &str, class: Class, seed: u64) -> TriMat {
    match class {
        Class::Graph => gen::erdos_renyi(s(2000), 8.0, seed),
        Class::PowerLaw => gen::powerlaw(s(2000), 2.0, 200, seed),
        Class::Banded => gen::banded(s(2000), 8, 0.6, seed),
        Class::Stencil => gen::laplacian_2d(s(45), s(45), seed),
        Class::FemBlocks => gen::fem_blocks(s(600), 3, 6, seed),
        Class::Constraint => gen::constraint(s(2000), 16, 300, seed),
        Class::Circuit => gen::circuit(s(2000), 8, 80, seed),
        Class::Planar => gen::planar_adjacency(s(2000), seed),
    }
}

/// The paper's 20 matrices, in table order.
pub const SUITE: [SuiteEntry; 20] = [
    SuiteEntry { name: "Erdos971", class: Class::Graph, seed: 9711 },
    SuiteEntry { name: "mcfe", class: Class::Banded, seed: 9712 },
    SuiteEntry { name: "blckhole", class: Class::Banded, seed: 9713 },
    SuiteEntry { name: "c-62", class: Class::Constraint, seed: 9714 },
    SuiteEntry { name: "OPF_10000", class: Class::Circuit, seed: 9715 },
    SuiteEntry { name: "lhr71", class: Class::Constraint, seed: 9716 },
    SuiteEntry { name: "stomach", class: Class::Stencil, seed: 9717 },
    SuiteEntry { name: "Orsreg_1", class: Class::Banded, seed: 9718 },
    SuiteEntry { name: "shipsec1", class: Class::FemBlocks, seed: 9719 },
    SuiteEntry { name: "shipsec5", class: Class::FemBlocks, seed: 9720 },
    SuiteEntry { name: "pdb1HYS", class: Class::FemBlocks, seed: 9721 },
    SuiteEntry { name: "or2010", class: Class::Planar, seed: 9722 },
    SuiteEntry { name: "Para-4", class: Class::FemBlocks, seed: 9723 },
    SuiteEntry { name: "G2_circuit", class: Class::Circuit, seed: 9724 },
    SuiteEntry { name: "144", class: Class::Graph, seed: 9725 },
    SuiteEntry { name: "cop20k_A", class: Class::FemBlocks, seed: 9726 },
    SuiteEntry { name: "consph", class: Class::FemBlocks, seed: 9727 },
    SuiteEntry { name: "Raj1", class: Class::PowerLaw, seed: 9728 },
    SuiteEntry { name: "3dtube", class: Class::FemBlocks, seed: 9729 },
    SuiteEntry { name: "net150", class: Class::Constraint, seed: 9730 },
];

/// Look a suite entry up by name.
pub fn by_name(name: &str) -> Option<&'static SuiteEntry> {
    SUITE.iter().find(|e| e.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_20_unique_names() {
        assert_eq!(SUITE.len(), 20);
        let mut names: Vec<&str> = SUITE.iter().map(|e| e.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 20);
    }

    #[test]
    fn all_matrices_build_and_validate() {
        // Keep this quick: scale down via env override is process-global,
        // so instead only spot-check a structurally diverse subset fully
        // and validate dims for the rest.
        for e in &SUITE {
            let m = e.build();
            assert!(m.nrows >= 32, "{} too small", e.name);
            assert!(m.nnz() > m.nrows, "{} suspiciously empty", e.name);
            m.validate().unwrap_or_else(|err| panic!("{}: {}", e.name, err));
        }
    }

    #[test]
    fn stats_match_built_matrix_and_memoize() {
        let e = by_name("Erdos971").unwrap();
        let s1 = e.stats_scaled(1.0);
        let direct = MatrixStats::of(&e.build_scaled(1.0));
        assert_eq!(s1, direct);
        // Second call hits the memo and returns the identical value.
        let s2 = e.stats_scaled(1.0);
        assert_eq!(s1, s2);
        // A different scale is a different memo entry.
        let s3 = e.stats_scaled(2.0);
        assert!(s3.nrows > s1.nrows);
    }

    #[test]
    fn deterministic_rebuild() {
        let a = by_name("Erdos971").unwrap().build();
        let b = by_name("Erdos971").unwrap().build();
        assert_eq!(a.entries, b.entries);
    }

    #[test]
    fn structural_diversity() {
        // The suite must exhibit diverse max-row-fill (this is what makes
        // different generated formats win on different matrices).
        let fills: Vec<f64> = ["blckhole", "consph", "Raj1", "net150"]
            .iter()
            .map(|n| {
                let m = by_name(n).unwrap().build();
                m.max_row_nnz() as f64 / (m.nnz() as f64 / m.nrows as f64)
            })
            .collect();
        let lo = fills.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = fills.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo > 3.0, "suite lacks fill diversity: {fills:?}");
    }
}
