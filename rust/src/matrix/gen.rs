//! Synthetic sparse-matrix generators reproducing the *structural
//! classes* of the paper's 20 UF-collection matrices (DESIGN.md §5):
//! Erdős–Rényi / power-law graphs, banded finite-difference stencils,
//! FEM meshes with dense node blocks, circuit/power networks, LP/netflow
//! constraint matrices. What drives the paper's results is the diversity
//! of row/column fill distributions, bandwidth and block structure —
//! which these generators control directly.

use crate::matrix::coo::TriMat;
use crate::util::rng::Rng;

fn val(rng: &mut Rng) -> f64 {
    // Values bounded away from zero so cancellation doesn't mask bugs.
    let v = rng.gen_f64_range(0.1, 2.0);
    if rng.gen_bool(0.5) { v } else { -v }
}

/// Every generator funnels its result through this check: the
/// generators are correct by construction, so a validation failure
/// here is a generator bug worth an immediate panic rather than a bad
/// reservoir leaking into storage builds and measurements.
fn finished(m: TriMat) -> TriMat {
    if let Err(e) = m.validate() {
        panic!("generator produced an invalid reservoir: {e}");
    }
    m
}

/// Uniform random matrix: each of `nnz` entries at a uniform (row, col).
pub fn uniform_random(nrows: usize, ncols: usize, nnz: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(nrows, ncols);
    for _ in 0..nnz {
        m.push(rng.gen_range(nrows), rng.gen_range(ncols), val(&mut rng));
    }
    m.sum_duplicates();
    finished(m)
}

/// Erdős–Rényi directed graph adjacency (Erdos971-class: small, sparse,
/// irregular). `avg_degree` expected out-degree.
pub fn erdos_renyi(n: usize, avg_degree: f64, seed: u64) -> TriMat {
    let nnz = (n as f64 * avg_degree) as usize;
    uniform_random(n, n, nnz, seed)
}

/// Power-law ("scale-free") graph: out-degrees drawn from a truncated
/// Pareto; models circuit (G2_circuit, Raj1) and web/social structure.
/// A handful of high-degree hub rows with many short rows.
pub fn powerlaw(n: usize, alpha: f64, max_degree: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(n, n);
    for i in 0..n {
        let deg = rng.gen_powerlaw(max_degree, alpha).min(n);
        let cols = rng.sample_distinct(n, deg);
        for c in cols {
            m.push(i, c, val(&mut rng));
        }
    }
    m.sum_duplicates();
    finished(m)
}

/// Banded matrix: `band` diagonals on each side of the main diagonal,
/// each kept with probability `fill`. Models FDM/oil-reservoir matrices
/// (Orsreg_1, blckhole-class).
pub fn banded(n: usize, band: usize, fill: f64, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(n, n);
    for i in 0..n {
        let lo = i.saturating_sub(band);
        let hi = (i + band + 1).min(n);
        for j in lo..hi {
            if i == j || rng.gen_bool(fill) {
                m.push(i, j, val(&mut rng));
            }
        }
    }
    finished(m)
}

/// 2-D 5-point Laplacian stencil on a `gx × gy` grid (classic PDE
/// structure; stomach/3dtube-class regularity).
pub fn laplacian_2d(gx: usize, gy: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let n = gx * gy;
    let mut m = TriMat::new(n, n);
    for y in 0..gy {
        for x in 0..gx {
            let i = y * gx + x;
            m.push(i, i, 4.0 + 0.01 * rng.gen_f64());
            if x > 0 {
                m.push(i, i - 1, -1.0 - 0.01 * rng.gen_f64());
            }
            if x + 1 < gx {
                m.push(i, i + 1, -1.0 - 0.01 * rng.gen_f64());
            }
            if y > 0 {
                m.push(i, i - gx, -1.0 - 0.01 * rng.gen_f64());
            }
            if y + 1 < gy {
                m.push(i, i + gx, -1.0 - 0.01 * rng.gen_f64());
            }
        }
    }
    finished(m)
}

/// FEM-style matrix: nodes carry `block`-sized dense blocks and couple to
/// a few random geometric neighbours (shipsec/consph/pdb1HYS-class: high
/// nnz/row, strong block structure).
pub fn fem_blocks(nodes: usize, block: usize, neighbors: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let n = nodes * block;
    let mut m = TriMat::new(n, n);
    for node in 0..nodes {
        // Self-coupling dense block.
        let mut coupled = vec![node];
        // Geometric-ish neighbours: close node ids couple (mesh locality),
        // plus occasional long-range coupling.
        for _ in 0..neighbors {
            let off = 1 + rng.gen_range(8);
            let nb = if rng.gen_bool(0.9) {
                if rng.gen_bool(0.5) { node.saturating_sub(off) } else { (node + off).min(nodes - 1) }
            } else {
                rng.gen_range(nodes)
            };
            coupled.push(nb);
        }
        coupled.sort_unstable();
        coupled.dedup();
        for &nb in &coupled {
            for bi in 0..block {
                for bj in 0..block {
                    m.push(node * block + bi, nb * block + bj, val(&mut rng));
                }
            }
        }
    }
    m.sum_duplicates();
    finished(m)
}

/// LP / network-constraint matrix: rectangular-feeling structure inside a
/// square: most rows short (2–4 entries), a few dense coupling rows
/// (c-62 / net150 / lhr71-class skew).
pub fn constraint(n: usize, dense_rows: usize, dense_len: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(n, n);
    for i in 0..n {
        let deg = 2 + rng.gen_range(3);
        for c in rng.sample_distinct(n, deg.min(n)) {
            m.push(i, c, val(&mut rng));
        }
    }
    for _ in 0..dense_rows {
        let i = rng.gen_range(n);
        for c in rng.sample_distinct(n, dense_len.min(n)) {
            m.push(i, c, val(&mut rng));
        }
    }
    m.sum_duplicates();
    finished(m)
}

/// Electrical-network matrix: sparse symmetric-ish stencil with a few
/// hub nodes (OPF_10000 / G2_circuit-class).
pub fn circuit(n: usize, hubs: usize, hub_degree: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(n, n);
    for i in 0..n {
        m.push(i, i, 2.0 + rng.gen_f64());
        // couple to 1-3 nearby nodes, symmetric
        let deg = 1 + rng.gen_range(3);
        for _ in 0..deg {
            let off = 1 + rng.gen_range(16);
            let j = (i + off) % n;
            let v = val(&mut rng);
            m.push(i, j, v);
            m.push(j, i, v);
        }
    }
    for _ in 0..hubs {
        let h = rng.gen_range(n);
        for c in rng.sample_distinct(n, hub_degree.min(n)) {
            let v = val(&mut rng);
            m.push(h, c, v);
            m.push(c, h, v);
        }
    }
    m.sum_duplicates();
    finished(m)
}

/// Census/redistricting adjacency (or2010-class): planar-ish graph —
/// short rows of nearly constant degree, strong locality.
pub fn planar_adjacency(n: usize, seed: u64) -> TriMat {
    let mut rng = Rng::new(seed);
    let mut m = TriMat::new(n, n);
    let side = (n as f64).sqrt() as usize + 1;
    for i in 0..n {
        m.push(i, i, 1.0 + rng.gen_f64());
        for &off in &[1usize, side, side - 1, side + 1] {
            if rng.gen_bool(0.8) && i + off < n {
                let v = val(&mut rng);
                m.push(i, i + off, v);
                m.push(i + off, i, v);
            }
        }
    }
    m.sum_duplicates();
    finished(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_dims_and_validity() {
        let m = uniform_random(100, 80, 500, 1);
        assert_eq!((m.nrows, m.ncols), (100, 80));
        assert!(m.nnz() > 400 && m.nnz() <= 500); // duplicates merged
        m.validate().unwrap();
    }

    #[test]
    fn generators_deterministic() {
        let a = powerlaw(200, 2.1, 50, 7);
        let b = powerlaw(200, 2.1, 50, 7);
        assert_eq!(a.entries, b.entries);
        let c = powerlaw(200, 2.1, 50, 8);
        assert_ne!(a.entries, c.entries);
    }

    #[test]
    fn banded_bandwidth_respected() {
        let m = banded(50, 3, 0.7, 2);
        m.validate().unwrap();
        for e in &m.entries {
            let d = (e.row as i64 - e.col as i64).abs();
            assert!(d <= 3);
        }
        // full diagonal present
        assert!(m.row_counts().iter().all(|&c| c >= 1));
    }

    #[test]
    fn laplacian_structure() {
        let m = laplacian_2d(8, 8, 0);
        m.validate().unwrap();
        assert_eq!(m.nrows, 64);
        // interior rows have 5 entries
        assert_eq!(m.max_row_nnz(), 5);
        assert_eq!(m.nnz(), 64 + 2 * (7 * 8) * 2); // diag + horiz + vert edges both dirs
    }

    #[test]
    fn fem_blocks_have_block_rows() {
        let m = fem_blocks(20, 3, 4, 3);
        m.validate().unwrap();
        assert_eq!(m.nrows, 60);
        // every row contains at least its own dense block → ≥ block entries
        assert!(m.row_counts().iter().all(|&c| c >= 3));
    }

    #[test]
    fn powerlaw_is_skewed() {
        let m = powerlaw(500, 2.0, 200, 11);
        m.validate().unwrap();
        let counts = m.row_counts();
        let max = *counts.iter().max().unwrap();
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert!(max as f64 > 5.0 * mean, "expected skew: max {max}, mean {mean}");
    }

    #[test]
    fn circuit_roughly_symmetric_pattern() {
        let m = circuit(300, 3, 30, 5);
        m.validate().unwrap();
        let set: std::collections::HashSet<(u32, u32)> =
            m.entries.iter().map(|e| (e.row, e.col)).collect();
        let sym = m.entries.iter().filter(|e| set.contains(&(e.col, e.row))).count();
        assert!(sym as f64 > 0.95 * m.nnz() as f64);
    }

    #[test]
    fn constraint_has_dense_rows() {
        let m = constraint(400, 4, 120, 9);
        m.validate().unwrap();
        assert!(m.max_row_nnz() >= 100);
    }

    #[test]
    fn planar_short_rows() {
        let m = planar_adjacency(400, 13);
        m.validate().unwrap();
        assert!(m.max_row_nnz() <= 10);
    }
}
